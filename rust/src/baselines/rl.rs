//! Reinforcement-learning baselines: PPO and DQN (§III.C, ConfuciuX-style
//! sequential parameter assignment).
//!
//! Both model genome construction as an episodic MDP: at step t the agent
//! chooses the value of gene t; the episode reward is the fitness of the
//! completed design (0 for dead individuals — the sparse-reward regime
//! the paper highlights).
//!
//! * **PPO** — factored categorical policy (one logits row per gene),
//!   clipped-surrogate updates with an EMA value baseline.
//! * **DQN** — Q(s, a) from a small in-tree MLP (`nn::Mlp`); the state
//!   encodes the current gene index and the normalized choices made so
//!   far; ε-greedy behaviour policy with a shrinking ε and a replay pass.

use super::nn::{sample_categorical, softmax, Mlp};
use super::space::{DirectSpace, MAX_ACTIONS};
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;

/// Shared: reward for one completed genome (0 for dead, otherwise a
/// monotone-decreasing squash of EDP against the best seen).
fn reward(edp: f64, valid: bool, best: &mut f64) -> f64 {
    if !valid || !edp.is_finite() {
        return 0.0;
    }
    *best = best.min(edp);
    1.0 / (1.0 + (edp / *best).ln().max(0.0))
}

// ---------------------------------------------------------------------------
// PPO
// ---------------------------------------------------------------------------

/// PPO hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct PpoConfig {
    /// Trust-region clip for the surrogate ratio.
    pub clip: f64,
    /// Policy learning rate.
    pub lr: f64,
    /// Episodes sampled per update.
    pub batch: usize,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig { clip: 0.2, lr: 0.15, batch: 24 }
    }
}

/// Config-parameterized core against a borrowed context (the registry /
/// portfolio entry point; telemetry accumulates in `ctx`).
pub fn ppo_with(ctx: &mut EvalContext, cfg: &PpoConfig, seed: u64) {
    let space = DirectSpace::new(ctx, seed);
    let mut rng = Pcg64::seeded(seed);
    let n = space.len();
    let clip = cfg.clip;
    let lr = cfg.lr;
    // Floor like the registry schema: a zero batch would spin forever
    // without consuming budget.
    let batch = cfg.batch.max(1);

    // Factored policy over the (quantized) raw action sets. Tile-gene
    // logits start with a downward ramp (prior toward small tile factors)
    // so the initial policy sees occasional rewards to learn from.
    let actions: Vec<Vec<u32>> = (0..n).map(|i| space.actions(i, MAX_ACTIONS)).collect();
    let mut logits: Vec<Vec<f64>> = actions
        .iter()
        .enumerate()
        .map(|(i, a)| {
            if space.is_tile_gene(i) {
                (0..a.len()).map(|k| -0.8 * k as f64).collect()
            } else {
                vec![0.0; a.len()]
            }
        })
        .collect();
    let mut baseline = 0.0f64;
    let mut best = f64::INFINITY;

    while !ctx.exhausted() {
        // Sample a batch of genomes + remember old probabilities.
        let mut genomes = Vec::with_capacity(batch);
        let mut chosen: Vec<Vec<usize>> = Vec::with_capacity(batch);
        let mut old_probs: Vec<Vec<f64>> = Vec::with_capacity(batch);
        for _ in 0..batch {
            let mut g = Vec::with_capacity(n);
            let mut acts = Vec::with_capacity(n);
            let mut ops = Vec::with_capacity(n);
            for (gi, row) in logits.iter().enumerate() {
                let probs = softmax(row);
                let a = sample_categorical(&probs, &mut rng);
                g.push(actions[gi][a]);
                acts.push(a);
                ops.push(probs[a]);
            }
            genomes.push(g);
            chosen.push(acts);
            old_probs.push(ops);
        }
        let results = space.eval(ctx, &genomes);
        if results.is_empty() {
            break;
        }
        let rewards: Vec<f64> =
            results.iter().map(|r| reward(r.edp, r.valid, &mut best)).collect();
        let mean_r = rewards.iter().sum::<f64>() / rewards.len() as f64;
        baseline = 0.9 * baseline + 0.1 * mean_r;

        // Two epochs of clipped updates.
        for _ in 0..2 {
            for (ep, acts) in chosen.iter().enumerate().take(results.len()) {
                let adv = rewards[ep] - baseline;
                if adv.abs() < 1e-12 {
                    continue;
                }
                for (gi, &a) in acts.iter().enumerate() {
                    let probs = softmax(&logits[gi]);
                    let ratio = probs[a] / old_probs[ep][gi].max(1e-12);
                    // Clipped surrogate: zero gradient outside the trust
                    // region in the direction of improvement.
                    let clipped = if adv > 0.0 {
                        ratio <= 1.0 + clip
                    } else {
                        ratio >= 1.0 - clip
                    };
                    if !clipped {
                        continue;
                    }
                    // ∇ log π gradient step for a categorical.
                    for (v, p) in probs.iter().enumerate() {
                        let indicator = if v == a { 1.0 } else { 0.0 };
                        logits[gi][v] += lr * adv * (indicator - p);
                    }
                }
            }
        }
    }
}

pub fn ppo(mut ctx: EvalContext, seed: u64) -> Outcome {
    ppo_with(&mut ctx, &PpoConfig::default(), seed);
    ctx.outcome("ppo")
}

// ---------------------------------------------------------------------------
// DQN
// ---------------------------------------------------------------------------

/// DQN hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct DqnConfig {
    /// Per-step discount inside the backward TD sweep.
    pub gamma: f64,
    /// Q-network learning rate.
    pub lr: f64,
    /// Hidden width of the in-tree MLP.
    pub hidden: usize,
}

impl Default for DqnConfig {
    fn default() -> Self {
        DqnConfig { gamma: 0.98, lr: 0.01, hidden: 32 }
    }
}

/// Config-parameterized core against a borrowed context (the registry /
/// portfolio entry point; telemetry accumulates in `ctx`).
pub fn dqn_with(ctx: &mut EvalContext, cfg: &DqnConfig, seed: u64) {
    let space = DirectSpace::new(ctx, seed);
    let mut rng = Pcg64::seeded(seed);
    let n = space.len();
    let actions: Vec<Vec<u32>> = (0..n).map(|i| space.actions(i, MAX_ACTIONS)).collect();
    let max_width = actions.iter().map(|a| a.len()).max().unwrap();

    // State: gene-position one-hot + normalized previous choice.
    let state_dim = n + 2;
    let mut qnet = Mlp::new(state_dim, cfg.hidden.max(1), max_width, &mut rng);
    let gamma = cfg.gamma;
    let lr = cfg.lr;
    let mut best = f64::INFINITY;
    let mut episode = 0usize;

    let encode_state = |pos: usize, prev_norm: f64| -> Vec<f64> {
        let mut s = vec![0.0; state_dim];
        if pos < n {
            s[pos] = 1.0;
        }
        s[n] = pos as f64 / n as f64;
        s[n + 1] = prev_norm;
        s
    };

    while !ctx.exhausted() {
        let eps = ((-(episode as f64) / 300.0).exp()).max(0.10);
        // Roll one episode.
        let mut genome = Vec::with_capacity(n);
        let mut transitions: Vec<(Vec<f64>, usize)> = Vec::with_capacity(n);
        let mut prev_norm = 0.0;
        for gi in 0..n {
            let width = actions[gi].len();
            let s = encode_state(gi, prev_norm);
            let a = if rng.chance(eps) {
                // Exploration biased toward small tile factors — the
                // unbiased choice almost never completes a live design,
                // so the Q function would never see a nonzero target.
                let u = rng.f64();
                let u = if gi >= n { u } else { u * u };
                ((u * width as f64) as usize).min(width - 1)
            } else {
                let q = qnet.forward(&s);
                (0..width).max_by(|&i, &j| q[i].partial_cmp(&q[j]).unwrap()).unwrap()
            };
            genome.push(actions[gi][a]);
            transitions.push((s, a));
            prev_norm = a as f64 / width.max(1) as f64;
        }
        let results = space.eval(ctx, std::slice::from_ref(&genome));
        let Some(result) = results.first().copied() else { break };
        let final_reward = reward(result.edp, result.valid, &mut best);

        // Backward TD sweep: terminal reward only, bootstrapped through
        // the episode (Monte-Carlo-flavoured n-step update).
        let mut target = final_reward;
        for (s, a) in transitions.iter().rev() {
            qnet.sgd_step(s, *a, target, lr);
            target *= gamma;
        }
        episode += 1;
    }
}

pub fn dqn(mut ctx: EvalContext, seed: u64) -> Outcome {
    dqn_with(&mut ctx, &DqnConfig::default(), seed);
    ctx.outcome("dqn")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn reward_shaping() {
        let mut best = f64::INFINITY;
        assert_eq!(reward(1e9, false, &mut best), 0.0);
        let r1 = reward(1e9, true, &mut best);
        assert!((r1 - 1.0).abs() < 1e-12); // first valid = best
        let r2 = reward(1e12, true, &mut best);
        assert!(r2 < r1 && r2 > 0.0);
    }

    #[test]
    fn ppo_runs_within_budget() {
        let o = ppo(ctx(800), 5);
        assert_eq!(o.method, "ppo");
        assert!(o.evals <= 800);
    }

    #[test]
    fn dqn_runs_within_budget() {
        let o = dqn(ctx(500), 6);
        assert_eq!(o.method, "dqn");
        assert!(o.evals <= 500);
    }

    #[test]
    fn rl_baselines_suffer_sparse_rewards() {
        // The paper's argument: RL drowns in invalid points of the raw
        // space (sparse rewards). Valid-exploration stays low.
        let p = ppo(ctx(2_000), 8);
        let d = dqn(ctx(2_000), 8);
        assert!(p.valid_ratio() < 0.7, "ppo valid {}", p.valid_ratio());
        assert!(d.valid_ratio() < 0.7, "dqn valid {}", d.valid_ratio());
    }
}
