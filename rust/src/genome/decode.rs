//! Genome → design decoding (Fig. 13 bottom half).
//!
//! A genome decodes into a [`Design`]: a complete [`Mapping`] plus a
//! [`SparseStrategy`]. Decoding is *total* — every in-range genome decodes
//! to a structurally well-formed design (it may still be invalid w.r.t.
//! resources or compatibility; that is the cost model's verdict, not a
//! decode failure).

use super::spec::{GeneKind, GenomeSpec, FORMAT_GENES_PER_TENSOR};
use crate::mapping::{permutation, Mapping, NUM_MAP_LEVELS};
use crate::sparse::{RankFormat, SgMechanism, SparseStrategy};
use crate::workload::Workload;

/// A fully decoded accelerator design point.
#[derive(Clone, Debug, PartialEq)]
pub struct Design {
    pub mapping: Mapping,
    pub strategy: SparseStrategy,
}

/// One materialized rank of a tensor under a mapping: dimension `dim`
/// tiled at mapping level `level` with extent `extent` (> 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RankId {
    pub level: usize,
    pub dim: usize,
    pub extent: u64,
}

/// Enumerate the materialized ranks of tensor `t` under `m`, ordered
/// outer→inner following the loop nest (level order, then the level's
/// permutation). These are the ranks the per-tensor format stack applies
/// to — e.g. the paper's example where P's ranks are (M2, K4, K5).
pub fn tensor_ranks(m: &Mapping, w: &Workload, t: usize) -> Vec<RankId> {
    let mut out = Vec::new();
    for level in 0..NUM_MAP_LEVELS {
        for &dim in &m.perm[level] {
            let extent = m.tile[level][dim];
            if extent > 1 && w.relevant(t, dim) {
                out.push(RankId { level, dim, extent });
            }
        }
    }
    out
}

/// Decode only the *mapping segment* (permutation + prime-factor genes,
/// `genome[..spec.format_start]`) into a [`Mapping`]. A pure function of
/// that segment — the evaluation engine memoizes it per distinct segment
/// (see `crate::search::engine`). `genome` may be a full genome or just
/// the mapping prefix.
pub fn decode_mapping(spec: &GenomeSpec, w: &Workload, genome: &[u32]) -> Mapping {
    let d = w.rank();
    let mut tile = vec![vec![1u64; d]; NUM_MAP_LEVELS];
    let mut perm = Vec::with_capacity(NUM_MAP_LEVELS);
    for level in 0..NUM_MAP_LEVELS {
        perm.push(permutation::decode(genome[level] as u64, d));
    }
    for (i, kind) in spec.kinds[..spec.format_start].iter().enumerate() {
        if let GeneKind::Factor { dim, prime, .. } = kind {
            let level = (genome[i] as usize - 1).min(NUM_MAP_LEVELS - 1);
            tile[level][*dim] *= prime;
        }
    }
    Mapping { tile, perm }
}

/// Decode the *strategy segments* (per-tensor format genes + S/G genes)
/// against an already-decoded mapping. Pure in (mapping, those genes).
pub fn decode_strategy(
    spec: &GenomeSpec,
    w: &Workload,
    mapping: &Mapping,
    genome: &[u32],
) -> SparseStrategy {
    let mut formats: [Vec<RankFormat>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for (t, fmts) in formats.iter_mut().enumerate() {
        let ranks = tensor_ranks(mapping, w, t);
        let genes = &genome
            [spec.format_start + t * FORMAT_GENES_PER_TENSOR..]
            [..FORMAT_GENES_PER_TENSOR];
        *fmts = assign_formats(&ranks, genes);
    }
    let sg = [
        SgMechanism::from_gene(genome[spec.sg_start]),
        SgMechanism::from_gene(genome[spec.sg_start + 1]),
        SgMechanism::from_gene(genome[spec.sg_start + 2]),
    ];
    SparseStrategy { formats, sg }
}

/// Decode a genome into a design. `genome` must be in-range for `spec`.
/// Composes the two segment-pure stages ([`decode_mapping`],
/// [`decode_strategy`]) so the staged and from-scratch evaluation paths
/// share this exact code.
pub fn decode(spec: &GenomeSpec, w: &Workload, genome: &[u32]) -> Design {
    debug_assert!(spec.in_range(genome), "genome out of range");
    let mapping = decode_mapping(spec, w, genome);
    let strategy = decode_strategy(spec, w, &mapping, genome);
    Design { mapping, strategy }
}

/// Per-rank format assignment (§IV.F): with k ≤ 5 ranks, the *last* k
/// genes of the 5-gene segment apply (outer→inner); with k > 5, the five
/// genes cover the first five ranks and deeper ranks default to
/// uncompressed.
pub fn assign_formats(ranks: &[RankId], genes: &[u32]) -> Vec<RankFormat> {
    let k = ranks.len();
    let g = genes.len(); // == 5
    if k <= g {
        genes[g - k..].iter().map(|&x| RankFormat::from_gene(x)).collect()
    } else {
        let mut out: Vec<RankFormat> =
            genes.iter().map(|&x| RankFormat::from_gene(x)).collect();
        out.extend(std::iter::repeat(RankFormat::Uncompressed).take(k - g));
        out
    }
}

/// Pretty-print a decoded design (mapping loop nest + strategy line).
pub fn describe(design: &Design, w: &Workload) -> String {
    format!("{}strategy: {}\n", design.mapping.render(w), design.strategy.describe())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{TENSOR_P, TENSOR_Q, TENSOR_Z};

    fn setup() -> (Workload, GenomeSpec) {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let s = GenomeSpec::for_workload(&w);
        (w, s)
    }

    /// Build the paper's Fig. 13 example: M2=4, K4=2, K5=4, N3=4.
    fn fig13_genome(w: &Workload, spec: &GenomeSpec) -> Vec<u32> {
        let mut g = vec![1u32; spec.len()];
        // Clear strategy segments (vec![1] would mean bitmask/gate).
        for i in spec.format_start..spec.len() {
            g[i] = 0;
        }
        // Factors: M has [2,2], K has [2,2,2], N has [2,2].
        // Assign M's two factors to level 2 (L2_T -> gene value 2).
        let mut fi = spec.factor_start;
        g[fi] = 2;
        g[fi + 1] = 2;
        fi += 2;
        // K: first factor -> level 4 (L3_T), last two -> level 5 (L3_S).
        g[fi] = 4;
        g[fi + 1] = 5;
        g[fi + 2] = 5;
        fi += 3;
        // N: both factors -> level 3 (L2_S).
        g[fi] = 3;
        g[fi + 1] = 3;
        // Formats for P: last three genes = B, B, CP (ranks M2,K4,K5).
        let pf = spec.format_start;
        g[pf + 2] = 1; // B
        g[pf + 3] = 1; // B
        g[pf + 4] = 3; // CP
        // SG: GLB = Skip Q<-P (gene 5), PEBuf = none, C = Gate both (3).
        g[spec.sg_start] = 5;
        g[spec.sg_start + 2] = 3;
        let _ = w;
        g
    }

    #[test]
    fn fig13_mapping_decodes() {
        let (w, spec) = setup();
        let g = fig13_genome(&w, &spec);
        let d = decode(&spec, &w, &g);
        assert!(d.mapping.respects(&w));
        assert_eq!(d.mapping.tile[1][0], 4); // M2 = 4
        assert_eq!(d.mapping.tile[3][1], 2); // K4 = 2
        assert_eq!(d.mapping.tile[4][1], 4); // K5 = 4
        assert_eq!(d.mapping.tile[2][2], 4); // N3 = 4
    }

    #[test]
    fn fig13_ranks_and_formats() {
        let (w, spec) = setup();
        let g = fig13_genome(&w, &spec);
        let d = decode(&spec, &w, &g);
        let ranks = tensor_ranks(&d.mapping, &w, TENSOR_P);
        assert_eq!(ranks.len(), 3); // M2, K4, K5
        assert_eq!((ranks[0].level, ranks[0].dim), (1, 0));
        assert_eq!((ranks[1].level, ranks[1].dim), (3, 1));
        assert_eq!((ranks[2].level, ranks[2].dim), (4, 1));
        assert_eq!(
            d.strategy.formats[TENSOR_P],
            vec![RankFormat::Bitmask, RankFormat::Bitmask, RankFormat::CoordinatePayload]
        );
        // Q's ranks: N3, K4, K5.
        assert_eq!(tensor_ranks(&d.mapping, &w, TENSOR_Q).len(), 3);
        // Z's ranks: M2, N3.
        assert_eq!(tensor_ranks(&d.mapping, &w, TENSOR_Z).len(), 2);
        assert_eq!(d.strategy.sg[0], SgMechanism::SkipQfromP);
        assert_eq!(d.strategy.sg[1], SgMechanism::None);
        assert_eq!(d.strategy.sg[2], SgMechanism::GateBoth);
    }

    #[test]
    fn every_random_genome_decodes_and_tiles() {
        let (w, spec) = setup();
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        for _ in 0..300 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            assert!(d.mapping.respects(&w)); // prime-factor encoding guarantee
            for t in 0..3 {
                assert_eq!(
                    d.strategy.formats[t].len(),
                    tensor_ranks(&d.mapping, &w, t).len()
                );
            }
        }
    }

    #[test]
    fn staged_decode_equals_monolithic_decode() {
        let (w, spec) = setup();
        let mut rng = crate::util::rng::Pcg64::seeded(17);
        for _ in 0..200 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            // The mapping stage sees only the mapping prefix…
            let m = decode_mapping(&spec, &w, spec.mapping_genes(&g));
            assert_eq!(m, d.mapping);
            // …and the strategy stage rebuilds the rest from it.
            let s = decode_strategy(&spec, &w, &m, &g);
            assert_eq!(s, d.strategy);
        }
    }

    #[test]
    fn rank_order_follows_permutation() {
        let (w, spec) = setup();
        let mut g = vec![1u32; spec.len()];
        // Put M and K both at L1_T; permutation decides rank order.
        for i in 0..2 {
            g[spec.factor_start + i] = 1;
        }
        for i in 2..5 {
            g[spec.factor_start + i] = 1;
        }
        g[0] = permutation::encode(&[1, 0, 2]) as u32; // K before M at L1
        let d = decode(&spec, &w, &g);
        let ranks = tensor_ranks(&d.mapping, &w, TENSOR_P);
        assert_eq!(ranks[0].dim, 1); // K rank first (outer)
        assert_eq!(ranks[1].dim, 0);
    }

    #[test]
    fn many_ranks_pad_with_uncompressed() {
        // Rank explosion: B dim + splits across all levels -> > 5 ranks.
        let w = Workload::spbmm("b", 4, 4, 16, 4, 0.5, 0.5);
        let spec = GenomeSpec::for_workload(&w);
        let mut g = vec![1u32; spec.len()];
        // Spread every factor across levels 1..5 cyclically.
        let mut level = 1;
        for i in spec.factor_start..spec.format_start {
            g[i] = level;
            level = level % 5 + 1;
        }
        // All format genes compressed (B).
        for i in spec.format_start..spec.sg_start {
            g[i] = 1;
        }
        let d = decode(&spec, &w, &g);
        for t in 0..3 {
            let ranks = tensor_ranks(&d.mapping, &w, t);
            if ranks.len() > 5 {
                // Deeper ranks must be uncompressed.
                assert!(d.strategy.formats[t][5..]
                    .iter()
                    .all(|f| *f == RankFormat::Uncompressed));
            }
        }
    }
}
