//! Sampling-based prior-work baselines: pure random search, the
//! Sparseloop-Mapper-like arm (random mapping under a manual sparse
//! strategy) and the SAGE-like arm (sparse-strategy search under a fixed
//! mapping).

use super::common;
use crate::genome::ops;
use crate::optimizer::checkpoint::{rng_from_json, rng_to_json};
use crate::optimizer::Optimizer;
use crate::search::{EvalContext, Outcome};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Random-search batch size (shared by the three sampling arms).
#[derive(Clone, Copy, Debug)]
pub struct RandomConfig {
    /// Genomes submitted per evaluation batch.
    pub batch: usize,
}

impl Default for RandomConfig {
    fn default() -> Self {
        RandomConfig { batch: 256 }
    }
}

/// Sparseloop-Mapper-like arm hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct SparseloopConfig {
    /// Genomes submitted per evaluation batch.
    pub batch: usize,
    /// Probability a sample pins the manual sparse strategy.
    pub manual_prob: f64,
}

impl Default for SparseloopConfig {
    fn default() -> Self {
        SparseloopConfig { batch: 256, manual_prob: 0.8 }
    }
}

/// SAGE-like arm hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct SageConfig {
    /// Population size of the format/strategy evolutionary loop.
    pub population: usize,
    /// Strategy genes re-sampled per child.
    pub mutations: usize,
}

impl Default for SageConfig {
    fn default() -> Self {
        SageConfig { population: 40, mutations: 2 }
    }
}

/// Uniform random search over the full joint genome (also the Fig. 7
/// design-space sampler), as a resumable [`Optimizer`]: the only live
/// state between batches is the RNG, captured by `suspend` and restored
/// by `resume`. The registry builds this directly; the legacy
/// [`pure_random_with`] free function delegates here, so both paths share
/// one implementation and stay bit-identical.
pub struct RandomOpt {
    cfg: RandomConfig,
    rng: Option<Pcg64>,
}

impl RandomOpt {
    pub fn new(cfg: RandomConfig) -> RandomOpt {
        RandomOpt { cfg, rng: None }
    }
}

impl Optimizer for RandomOpt {
    fn label(&self) -> &str {
        "random"
    }

    fn run(&mut self, ctx: &mut EvalContext, seed: u64) {
        let rng = self.rng.get_or_insert_with(|| Pcg64::seeded(seed));
        let spec = ctx.spec.clone();
        let batch = self.cfg.batch.max(1);
        while !ctx.should_pause() {
            let n = ctx.remaining().min(batch);
            let genomes: Vec<_> = (0..n).map(|_| spec.random(rng)).collect();
            ctx.eval_batch(&genomes);
        }
    }

    fn suspend(&self) -> Option<Json> {
        Some(Json::obj(vec![(
            "rng",
            match &self.rng {
                Some(rng) => rng_to_json(rng),
                None => Json::Null,
            },
        )]))
    }

    fn resume(&mut self, state: &Json) -> anyhow::Result<()> {
        self.rng = match state.get("rng") {
            None | Some(Json::Null) => None,
            Some(j) => Some(rng_from_json(j)?),
        };
        Ok(())
    }
}

/// Config-parameterized core (the legacy free-function entry point;
/// telemetry accumulates in `ctx`). One fresh [`RandomOpt`] per call —
/// bit-identical to the pre-trait loop.
pub fn pure_random_with(ctx: &mut EvalContext, cfg: &RandomConfig, seed: u64) {
    RandomOpt::new(*cfg).run(ctx, seed);
}

pub fn pure_random(mut ctx: EvalContext, seed: u64) -> Outcome {
    pure_random_with(&mut ctx, &RandomConfig::default(), seed);
    ctx.outcome("random")
}

/// Sparseloop-Mapper-like: random sampling over *mapping* genes with the
/// sparse strategy pinned to the manual configuration (§V: "mapping
/// exploration under a manually specified sparse strategy", with the
/// manual settings included in its sampling space).
pub fn sparseloop_mapper_with(ctx: &mut EvalContext, cfg: &SparseloopConfig, seed: u64) {
    let mut rng = Pcg64::seeded(seed);
    let spec = ctx.spec.clone();
    let manual = common::manual_strategy_genes(&spec, ctx.workload());
    let batch = cfg.batch.max(1);
    while !ctx.exhausted() {
        let n = ctx.remaining().min(batch);
        let genomes: Vec<_> = (0..n)
            .map(|_| {
                let mut g = spec.random(&mut rng);
                // Most samples pin the manual strategy; a slice of the
                // budget samples strategies randomly too (the paper folded
                // the manual settings into the random space).
                if rng.chance(cfg.manual_prob) {
                    common::apply(&mut g, &manual);
                }
                g
            })
            .collect();
        ctx.eval_batch(&genomes);
    }
}

pub fn sparseloop_mapper(mut ctx: EvalContext, seed: u64) -> Outcome {
    sparseloop_mapper_with(&mut ctx, &SparseloopConfig::default(), seed);
    ctx.outcome("sparseloop")
}

/// SAGE-like: the mapping is *fixed* to a reasonable heuristic; a small
/// evolutionary search explores only the compression-format and S/G
/// genes (SAGE explores formats; it never re-tiles).
pub fn sage_like_with(ctx: &mut EvalContext, cfg: &SageConfig, seed: u64) {
    let mut rng = Pcg64::seeded(seed);
    let spec = ctx.spec.clone();
    let mapping = common::heuristic_mapping_genes(&spec, ctx.workload());
    let strategy_idx = common::strategy_gene_indices(&spec);

    let fixed_base = {
        let mut g = spec.random(&mut rng);
        common::apply(&mut g, &mapping);
        g
    };

    // Seed population: random strategies over the fixed mapping.
    let pop_size = cfg.population.max(2);
    let mut pop: Vec<(Vec<u32>, f64)> = Vec::new();
    let genomes: Vec<_> = (0..pop_size)
        .map(|_| {
            let mut g = fixed_base.clone();
            for &i in &strategy_idx {
                g[i] = spec.ranges[i].sample(&mut rng);
            }
            g
        })
        .collect();
    for (g, r) in genomes.iter().zip(ctx.eval_batch(&genomes)) {
        pop.push((g.clone(), if r.valid { r.edp } else { f64::INFINITY }));
    }

    while !ctx.exhausted() {
        pop.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        pop.truncate(pop_size / 2);
        let mut children = Vec::new();
        while children.len() < pop_size && !ctx.exhausted() {
            let pa = &pop[rng.index(pop.len())].0;
            let pb = &pop[rng.index(pop.len())].0;
            let mut c = ops::uniform_crossover(pa, pb, &mut rng);
            // Mutate a couple of strategy genes; mapping stays fixed.
            for _ in 0..cfg.mutations {
                let i = strategy_idx[rng.index(strategy_idx.len())];
                c[i] = spec.ranges[i].sample(&mut rng);
            }
            common::apply(&mut c, &mapping);
            children.push(c);
        }
        let results = ctx.eval_batch(&children);
        for (g, r) in children.iter().zip(results) {
            pop.push((g.clone(), if r.valid { r.edp } else { f64::INFINITY }));
        }
    }
}

pub fn sage_like(mut ctx: EvalContext, seed: u64) -> Outcome {
    sage_like_with(&mut ctx, &SageConfig::default(), seed);
    ctx.outcome("sage-like")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 64, 128, 64, 0.2, 0.2);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn random_consumes_exact_budget() {
        let o = pure_random(ctx(500), 1);
        assert_eq!(o.evals, 500);
        assert_eq!(o.method, "random");
    }

    #[test]
    fn sparseloop_finds_valid_designs() {
        let o = sparseloop_mapper(ctx(1_500), 2);
        assert!(o.found_valid());
        // The manual strategy should lift the valid ratio well above the
        // pure-random joint space's.
        let r = pure_random(ctx(1_500), 2);
        assert!(o.valid_ratio() >= r.valid_ratio() * 0.8);
    }

    #[test]
    fn sage_like_keeps_mapping_fixed() {
        let o = sage_like(ctx(1_000), 3);
        assert_eq!(o.method, "sage-like");
        assert!(o.evals <= 1_000);
        // With a sane fixed mapping it should find something valid.
        assert!(o.found_valid());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = sparseloop_mapper(ctx(400), 9);
        let b = sparseloop_mapper(ctx(400), 9);
        assert_eq!(a.best_edp, b.best_edp);
    }
}
