//! High-Sensitivity Hypercube Initialization (HSHI, §IV.D).
//!
//! The design space is partitioned into hypercubes along the
//! high-sensitivity gene axes. One valid individual is sought per
//! hypercube with a small random-search budget (paper: ~100 hypercubes ×
//! ≤20 tries); low-sensitivity genes are drawn from the valid pool
//! collected during calibration when available. This yields an initial
//! population that is simultaneously *valid-rich* and *diverse in the
//! genes that matter*.

use super::sensitivity::Sensitivity;
use crate::genome::{Genome, GenomeSpec};
use crate::search::EvalContext;
use crate::util::rng::Pcg64;

/// HSHI hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct HshiConfig {
    /// Number of hypercubes (= target initial population size).
    pub hypercubes: usize,
    /// Random-search tries per hypercube.
    pub tries_per_cube: usize,
}

impl Default for HshiConfig {
    fn default() -> Self {
        HshiConfig { hypercubes: 100, tries_per_cube: 20 }
    }
}

/// A hypercube: one stratum index per high-sensitivity gene.
fn cube_coordinates(
    spec: &GenomeSpec,
    high: &[usize],
    cube_idx: usize,
    strata: &[u32],
) -> Vec<(usize, u32, u32)> {
    // Decompose cube_idx in mixed radix over the strata counts, yielding
    // (gene, stratum_lo, stratum_hi) bounds per high-sensitivity gene.
    let mut out = Vec::with_capacity(high.len());
    let mut rem = cube_idx as u64;
    for (&gene, &k) in high.iter().zip(strata) {
        let r = spec.ranges[gene];
        let s = (rem % k as u64) as u32;
        rem /= k as u64;
        let w = r.width();
        let lo = r.lo + s * w / k;
        let hi = r.lo + ((s + 1) * w / k).max(s * w / k + 1).min(w) - 1;
        out.push((gene, lo, hi.max(lo).min(r.hi)));
    }
    out
}

/// Per-gene strata counts whose product is ≈ `target` hypercubes.
fn strata_counts(spec: &GenomeSpec, high: &[usize], target: usize) -> Vec<u32> {
    if high.is_empty() {
        return Vec::new();
    }
    // Even split in log space, capped by each gene's range width.
    let per = (target as f64).powf(1.0 / high.len() as f64).round().max(1.0) as u32;
    high.iter().map(|&g| per.min(spec.ranges[g].width()).max(1)).collect()
}

/// Result of the initialization.
#[derive(Clone, Debug)]
pub struct HshiResult {
    pub population: Vec<Genome>,
    /// How many hypercubes yielded a valid individual within budget.
    pub cubes_hit: usize,
    pub cubes_total: usize,
    pub evals_spent: usize,
}

/// What one [`HshiMachine::step`] call ended with.
pub enum HshiStep {
    /// Every requested hypercube has been visited.
    Done(HshiResult),
    /// The context asked to pause (budget/fence exhausted or suspension
    /// requested). Call `step` again later to continue, or
    /// [`HshiMachine::force_finish`] to settle for the cubes visited.
    Paused,
}

/// Resumable HSHI state machine.
///
/// [`initialize`] drives it to completion in one call; the ES optimizer
/// keeps one alive across suspend/resume cycles. Pausing happens only at
/// the top of the per-cube loop, before the cube index draw, so a
/// paused-and-resumed initialization replays bit-identically.
pub struct HshiMachine {
    pub(crate) cfg: HshiConfig,
    pub(crate) strata: Vec<u32>,
    pub(crate) total_cubes: u64,
    pub(crate) n_cubes: usize,
    /// Next cube to visit.
    pub(crate) cube: usize,
    /// Absolute `ctx.used()` at machine creation (for `evals_spent`).
    pub(crate) start: usize,
    pub(crate) population: Vec<Genome>,
}

impl HshiMachine {
    pub fn new(ctx: &EvalContext, sens: &Sensitivity, cfg: HshiConfig) -> HshiMachine {
        let spec = ctx.spec.clone();
        let strata = strata_counts(&spec, &sens.high, cfg.hypercubes);
        let total_cubes: u64 = strata.iter().map(|&k| k as u64).product::<u64>().max(1);
        let n_cubes = cfg.hypercubes.min(total_cubes as usize).max(1);
        HshiMachine {
            cfg,
            strata,
            total_cubes,
            n_cubes,
            cube: 0,
            start: ctx.used(),
            population: Vec::with_capacity(n_cubes),
        }
    }

    /// Advance until done or the context wants to pause.
    pub fn step(
        &mut self,
        ctx: &mut EvalContext,
        sens: &Sensitivity,
        rng: &mut Pcg64,
    ) -> HshiStep {
        let spec = ctx.spec.clone();
        while self.cube < self.n_cubes {
            if ctx.should_pause() {
                return HshiStep::Paused;
            }
            // Pick a distinct cube (when more cubes exist than requested,
            // sample them uniformly without replacement semantics not
            // needed).
            let cube_idx = if self.total_cubes as usize == self.n_cubes {
                self.cube
            } else {
                rng.below(self.total_cubes) as usize
            };
            let bounds = cube_coordinates(&spec, &sens.high, cube_idx, &self.strata);

            let mut best: Option<Genome> = None;
            for _ in 0..self.cfg.tries_per_cube {
                if ctx.exhausted() {
                    break;
                }
                // Low-sensitivity genes: reuse a valid combination from
                // the calibration pool when available, else random.
                let mut g = if !sens.valid_pool.is_empty() && rng.chance(0.7) {
                    rng.choose(&sens.valid_pool).clone()
                } else {
                    spec.random(rng)
                };
                // High-sensitivity genes: uniform within this cube's
                // stratum.
                for &(gene, lo, hi) in &bounds {
                    g[gene] = rng.range_u32(lo, hi);
                }
                let r = ctx.eval_one(&g);
                match r {
                    Some(r) if r.valid => {
                        best = Some(g);
                        break;
                    }
                    Some(_) => {
                        // Keep the last invalid candidate as a fallback
                        // seed (better than an empty slot; it still
                        // carries cube diversity).
                        if best.is_none() {
                            best = Some(g);
                        }
                    }
                    None => break,
                }
            }
            if let Some(g) = best {
                self.population.push(g);
            }
            self.cube += 1;
            // Exhaustion is caught at the loop top on the next pass, so a
            // fenced (portfolio) run can re-enter and finish later cubes.
        }
        HshiStep::Done(self.force_finish(ctx))
    }

    /// Settle with the cubes visited so far — what a plain
    /// budget-exhausted run gets.
    pub fn force_finish(&self, ctx: &EvalContext) -> HshiResult {
        HshiResult {
            population: self.population.clone(),
            // The per-cube break above only fires on a valid hit, so the
            // population length counts the cubes that landed one (invalid
            // fallback seeds included — they still carry cube diversity).
            cubes_hit: self.population.len(),
            cubes_total: self.n_cubes,
            evals_spent: ctx.used() - self.start,
        }
    }
}

/// Run HSHI to completion. Falls back to plain random sampling when
/// there are no high-sensitivity genes (degenerate calibration).
pub fn initialize(
    ctx: &mut EvalContext,
    sens: &Sensitivity,
    cfg: HshiConfig,
    rng: &mut Pcg64,
) -> HshiResult {
    let mut m = HshiMachine::new(ctx, sens, cfg);
    match m.step(ctx, sens, rng) {
        HshiStep::Done(r) => r,
        // Only reachable when the budget ran out mid-initialization; the
        // remaining cubes would have been skipped as no-ops anyway.
        HshiStep::Paused => m.force_finish(ctx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::es::sensitivity::{calibrate, CalibConfig};
    use crate::search::{Backend, EvalContext};
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("mm", 64, 64, 64, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn strata_product_close_to_target() {
        let c = ctx(10);
        let high = vec![0, 1, 2]; // three perm genes, width 6
        let strata = strata_counts(&c.spec, &high, 100);
        let prod: u32 = strata.iter().product();
        assert!((27..=216).contains(&prod), "prod={prod}");
    }

    #[test]
    fn cube_bounds_within_ranges() {
        let c = ctx(10);
        let high = vec![0, 5];
        let strata = strata_counts(&c.spec, &high, 16);
        let total: u64 = strata.iter().map(|&k| k as u64).product();
        for idx in 0..total as usize {
            for (gene, lo, hi) in cube_coordinates(&c.spec, &high, idx, &strata) {
                let r = c.spec.ranges[gene];
                assert!(r.lo <= lo && lo <= hi && hi <= r.hi, "gene {gene}: {lo}..{hi}");
            }
        }
    }

    #[test]
    fn initialization_yields_population() {
        let mut c = ctx(8_000);
        let mut rng = Pcg64::seeded(31);
        let sens = calibrate(&mut c, CalibConfig::default(), &mut rng);
        let cfg = HshiConfig { hypercubes: 40, tries_per_cube: 10 };
        let out = initialize(&mut c, &sens, cfg, &mut rng);
        assert!(!out.population.is_empty());
        assert!(out.population.len() <= 40);
        for g in &out.population {
            assert!(c.spec.in_range(g));
        }
        assert!(out.evals_spent > 0);
    }

    #[test]
    fn hshi_beats_random_on_validity() {
        // The paper's motivation: HSHI yields more valid individuals than
        // uniform random sampling of the same size.
        let mut c = ctx(12_000);
        let mut rng = Pcg64::seeded(33);
        let sens = calibrate(&mut c, CalibConfig::default(), &mut rng);
        let cfg = HshiConfig { hypercubes: 30, tries_per_cube: 15 };
        let out = initialize(&mut c, &sens, cfg, &mut rng);
        let hshi_valid = {
            // Re-evaluate through a fresh context (doesn't disturb budget
            // accounting of the main one).
            let mut c2 = ctx(10_000);
            let res = c2.eval_batch(&out.population);
            res.iter().filter(|r| r.valid).count() as f64 / res.len() as f64
        };
        let random_valid = {
            let mut c3 = ctx(10_000);
            let genomes: Vec<_> =
                (0..out.population.len()).map(|_| c3.spec.random(&mut rng)).collect();
            let res = c3.eval_batch(&genomes);
            res.iter().filter(|r| r.valid).count() as f64 / res.len() as f64
        };
        assert!(
            hshi_valid >= random_valid,
            "hshi {hshi_valid} < random {random_valid}"
        );
    }
}
