//! The SparseMap evolution strategy (§IV): sensitivity calibration,
//! high-sensitivity hypercube initialization, annealing mutation,
//! sensitivity-aware crossover and the generational loop.

pub mod hypercube;
pub mod operators;
pub mod population;
pub mod sensitivity;
pub mod sparsemap;

pub use hypercube::{HshiConfig, HshiResult};
pub use population::{Individual, lhs_init};
pub use sensitivity::{CalibConfig, Sensitivity};
pub use sparsemap::{
    run_sparsemap, run_sparsemap_with, EsConfig, EsOpt, EsVariant, SparseMapSearch,
};
