//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §Experiments, E1–E9) plus beyond-paper studies (the
//! [`patterns`] sparsity-pattern sweep). Each driver returns the rendered
//! report and writes CSV next to it so plots can be regenerated.

pub mod fig10;
pub mod fig17;
pub mod fig18;
pub mod fig2;
pub mod fig7;
pub mod patterns;
pub mod table4;

use crate::api::SearchRequest;
use crate::arch::Platform;
use crate::search::EvalContext;
use crate::workload::Workload;
use std::path::{Path, PathBuf};

/// Common knobs for all experiment drivers.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Sample budget per search arm (paper: 20 000).
    pub budget: usize,
    pub seed: u64,
    /// Output directory for CSV artifacts.
    pub out_dir: PathBuf,
    /// Use the PJRT AOT evaluator (default) or the native model.
    pub use_pjrt: bool,
    /// Worker threads for independent arms.
    pub threads: usize,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            budget: 20_000,
            seed: 42,
            out_dir: PathBuf::from("results"),
            use_pjrt: false,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

impl ExpConfig {
    /// Lower this config into a [`SearchRequest`] for one arm — the
    /// single place experiment knobs map onto the public API. Matrix
    /// drivers that fan out one-arm-per-thread (`fig17`, `table4`)
    /// override `threads` to 1 per arm instead — nesting a context pool
    /// inside an arm pool would only oversubscribe the machine.
    pub fn request(&self, workload: Workload, platform: Platform) -> SearchRequest {
        SearchRequest::new()
            .workload(workload)
            .platform(platform)
            .budget(self.budget)
            .seed(self.seed)
            .threads(self.threads)
            .pjrt(self.use_pjrt)
    }

    /// Build a fresh evaluation context for one arm through the API,
    /// with the evaluation pool attached (population batches fan out
    /// across `threads`).
    ///
    /// Note: the PJRT backend compiles the artifact per context; drivers
    /// that fan out across threads use the native backend inside workers
    /// (the two are cross-validated — see `rust/tests/runtime_xla.rs`).
    pub fn context(&self, workload: Workload, platform: Platform) -> EvalContext {
        self.request(workload, platform)
            .build()
            .expect("experiment workloads/platforms always validate")
            .into_context()
    }
}

/// Write a CSV file under the configured output dir.
pub fn write_csv(dir: &Path, name: &str, csv: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, csv)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = ExpConfig::default();
        assert_eq!(c.budget, 20_000);
        assert!(!c.use_pjrt);
    }

    #[test]
    fn context_builds_native() {
        let c = ExpConfig { budget: 10, ..Default::default() };
        let ctx = c.context(Workload::spmm("t", 4, 4, 4, 0.5, 0.5), Platform::edge());
        assert_eq!(ctx.budget, 10);
    }

    #[test]
    fn context_attaches_eval_pool() {
        let w = || Workload::spmm("t", 4, 4, 4, 0.5, 0.5);
        let par = ExpConfig { budget: 10, threads: 3, ..Default::default() };
        assert_eq!(par.context(w(), Platform::edge()).threads(), 3);
        let serial = ExpConfig { budget: 10, threads: 1, ..Default::default() };
        assert_eq!(serial.context(w(), Platform::edge()).threads(), 1);
    }

    #[test]
    fn write_csv_roundtrip() {
        let dir = std::env::temp_dir().join("sparsemap_csv_test");
        let p = write_csv(&dir, "x.csv", "a,b\n1,2\n").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "a,b\n1,2\n");
    }
}
