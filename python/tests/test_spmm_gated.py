"""Gated-SpMM Pallas kernel vs dense oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, spmm_gated


def random_case(rng, m, k, n, dp, dq):
    p = rng.standard_normal((m, k)).astype(np.float32)
    q = rng.standard_normal((k, n)).astype(np.float32)
    pm = (rng.uniform(size=(m, k)) < dp).astype(np.float32)
    qm = (rng.uniform(size=(k, n)) < dq).astype(np.float32)
    return p, q, pm, qm


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    mb=st.integers(1, 3),
    k=st.sampled_from([16, 64, 96]),
    n=st.sampled_from([16, 64]),
    dp=st.floats(0.05, 1.0),
    dq=st.floats(0.05, 1.0),
)
def test_matches_ref(seed, mb, k, n, dp, dq):
    rng = np.random.default_rng(seed)
    m = mb * spmm_gated.BLOCK_M
    p, q, pm, qm = random_case(rng, m, k, n, dp, dq)
    z, eff = spmm_gated.spmm_gated_pallas(p, q, pm, qm)
    z_ref, eff_ref = ref.spmm_gated_ref(p, q, pm, qm)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(eff), float(eff_ref), rtol=1e-6)


def test_dense_case_is_plain_matmul():
    rng = np.random.default_rng(7)
    p, q, _, _ = random_case(rng, 32, 16, 16, 1.0, 1.0)
    ones_p = np.ones_like(p)
    ones_q = np.ones_like(q)
    z, eff = spmm_gated.spmm_gated_pallas(p, q, ones_p, ones_q)
    np.testing.assert_allclose(np.asarray(z), p @ q, rtol=1e-5, atol=1e-5)
    assert float(eff) == 32 * 16 * 16


def test_all_zero_mask_kills_everything():
    rng = np.random.default_rng(8)
    p, q, _, qm = random_case(rng, 32, 16, 16, 0.5, 0.5)
    zm = np.zeros_like(p)
    z, eff = spmm_gated.spmm_gated_pallas(p, q, zm, qm)
    assert float(eff) == 0.0
    np.testing.assert_allclose(np.asarray(z), 0.0)


def test_effectual_count_matches_density_expectation():
    rng = np.random.default_rng(9)
    m, k, n = 64, 96, 64
    p, q, pm, qm = random_case(rng, m, k, n, 0.5, 0.25)
    _, eff = spmm_gated.spmm_gated_pallas(p, q, pm, qm)
    total = m * k * n
    # E[effectual] = dp*dq*total; loose 3-sigma-ish band.
    assert 0.08 * total < float(eff) < 0.18 * total
