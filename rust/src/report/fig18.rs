//! E7 / Fig. 18 — ablation: standard ES (direct encoding + LHS) vs
//! ES + PFCE (prime-factor & Cantor encoding) vs full SparseMap
//! (+ customized operators and HSHI), as population-mean-EDP convergence
//! curves on one SpMM (mm3) and one SpConv (conv4) at cloud.

use super::{write_csv, ExpConfig};
use crate::search::Outcome;
use crate::util::table::{sci, Table};

pub const ABLATION_ARMS: &[&str] = &["es-direct", "es-pfce", "sparsemap"];
pub const ABLATION_WORKLOADS: &[&str] = &["mm3", "conv4"];

pub fn run_arms(cfg: &ExpConfig) -> Vec<Outcome> {
    let mut out = Vec::new();
    for wl in ABLATION_WORKLOADS {
        for method in ABLATION_ARMS {
            let report = crate::api::SearchRequest::new()
                .workload_named(wl)
                .platform_named("cloud")
                .method(method)
                .budget(cfg.budget)
                .seed(cfg.seed)
                .threads(cfg.threads)
                .build()
                .expect("ablation arms validate")
                .run()
                .expect("ablation search");
            out.push(report.into_outcome());
        }
    }
    out
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<String> {
    let outcomes = run_arms(cfg);
    let mut csv = String::from("workload,arm,evals,best_edp\n");
    for o in &outcomes {
        for &(e, v) in &o.curve {
            csv.push_str(&format!("{},{},{},{:.6e}\n", o.workload, o.method, e, v));
        }
    }
    write_csv(&cfg.out_dir, "fig18.csv", &csv)?;

    let mut table = Table::new(&["workload", "arm", "best_edp", "valid_ratio"]);
    for o in &outcomes {
        table.row(vec![
            o.workload.clone(),
            o.method.clone(),
            if o.found_valid() { sci(o.best_edp) } else { "-".into() },
            format!("{:.1}%", 100.0 * o.valid_ratio()),
        ]);
    }
    Ok(format!(
        "Fig. 18 — ablation convergence (cloud, budget {} per arm)\n{}",
        cfg.budget,
        table.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_ordering_holds() {
        // es-direct (dead-offspring-ridden) should not beat full
        // SparseMap at equal budget; PFCE should sit at or above direct.
        let cfg = ExpConfig { budget: 2_500, seed: 21, ..Default::default() };
        let run = |m: &str| {
            crate::api::SearchRequest::new()
                .workload_named("mm3")
                .platform_named("cloud")
                .method(m)
                .budget(cfg.budget)
                .seed(cfg.seed)
                .build()
                .unwrap()
                .run()
                .unwrap()
                .into_outcome()
        };
        let direct = run("es-direct");
        let pfce = run("es-pfce");
        let full = run("sparsemap");
        // Valid-exploration ordering is the robust part of the claim.
        assert!(pfce.valid_ratio() > direct.valid_ratio());
        assert!(full.found_valid());
        // Full SparseMap should beat the direct-encoding ES on EDP.
        assert!(full.best_edp <= direct.best_edp);
    }
}
