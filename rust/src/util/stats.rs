//! Descriptive statistics helpers used by telemetry, sensitivity
//! calibration and the report generators.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean of strictly-positive values (used for the paper's
/// "average EDP reduction" headline ratios). Non-positive entries are
/// skipped.
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        0.0
    } else {
        (logs.iter().sum::<f64>() / logs.len() as f64).exp()
    }
}

/// Minimum, ignoring NaN; +inf for empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min)
}

/// Maximum, ignoring NaN; -inf for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile (`q` in [0,1]) of a sorted copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Online exponential moving average.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Streaming min/mean/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn geomean_ratios() {
        let xs = [10.0, 1000.0];
        assert!((geomean(&xs) - 100.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[0.0, 4.0, 9.0]) - 6.0).abs() < 1e-9); // zeros skipped
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn summary_stream() {
        let mut s = Summary::new();
        for x in [2.0, -1.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.n, 3);
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
