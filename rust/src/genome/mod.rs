//! Genome encoding/decoding (§IV.B/C/F/G): a sparse tensor accelerator
//! design as a 1-D integer array.
//!
//! * [`spec`] — per-workload gene layout and ranges (prime-factor genes
//!   guarantee dimension-tiling constraints by construction);
//! * [`decode`] — genome → [`decode::Design`] (mapping + sparse strategy);
//! * [`ops`] — elementary mutation/crossover building blocks.

pub mod decode;
pub mod ops;
pub mod spec;

pub use decode::{
    assign_formats, decode, decode_mapping, decode_strategy, describe, tensor_ranks, Design,
    RankId,
};
pub use spec::{GeneKind, GeneRange, GenomeSpec, FORMAT_GENES_PER_TENSOR, SG_SITES};

/// A genome is a plain gene vector; all structure lives in [`GenomeSpec`].
pub type Genome = Vec<u32>;
