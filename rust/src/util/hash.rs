//! An in-tree fast hasher (Fx-style multiply-rotate) for hot-path maps.
//!
//! The offline vendor set has no `rustc-hash`/`ahash`, and `std`'s
//! default SipHash is DoS-resistant but ~5x slower than needed for the
//! evaluation engine, which hashes short `u32` gene slices millions of
//! times per search. Genome keys are attacker-free internal data, so the
//! non-cryptographic Fx construction (the rustc interner's hasher) is the
//! right trade: one rotate + xor + multiply per word.

use std::hash::{BuildHasher, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher over 8-byte words.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`] — plug into
/// `HashMap::with_hasher(FxBuildHasher::default())`.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// A `HashMap` keyed with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash + ?Sized>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_discriminating() {
        let a = vec![1u32, 2, 3, 4];
        let b = vec![1u32, 2, 3, 5];
        assert_eq!(hash_of(&a), hash_of(&a.clone()));
        assert_ne!(hash_of(&a), hash_of(&b));
    }

    #[test]
    fn slice_and_owned_agree() {
        // HashMap<Arc<[u32]>, _> looks up by &[u32] via Borrow: both
        // sides must hash identically.
        let owned: std::sync::Arc<[u32]> = std::sync::Arc::from(&[7u32, 8, 9][..]);
        let slice: &[u32] = &[7, 8, 9];
        assert_eq!(hash_of(&*owned), hash_of(&slice.to_vec()[..]));
        assert_eq!(hash_of(&*owned), {
            let mut h = FxHasher::default();
            slice.hash(&mut h);
            h.finish()
        });
    }

    #[test]
    fn fx_map_works_end_to_end() {
        let mut m: FxHashMap<Vec<u32>, usize> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert(vec![i, i * 2, i * 3], i as usize);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u32 {
            assert_eq!(m.get(&vec![i, i * 2, i * 3]), Some(&(i as usize)));
        }
    }

    #[test]
    fn byte_tail_handling() {
        // write() must not collide trivially on short/unaligned inputs.
        // (Non-zero bytes: the zero-padded tail word makes [0x00]
        // indistinguishable from [] by design — callers that care hash a
        // length prefix, as std's slice Hash impls do.)
        let mut seen = std::collections::HashSet::new();
        for len in 0..24usize {
            let bytes: Vec<u8> = (1..=len as u8).collect();
            let mut h = FxHasher::default();
            h.write(&bytes);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 24);
    }
}
