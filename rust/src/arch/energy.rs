//! Per-access energy constants (12 nm class).
//!
//! The paper uses the same 12 nm process as DSTC and evaluates through
//! TimeloopV2 + an Accelergy-style energy backend. We use public
//! Accelergy/Eyeriss-lineage estimates scaled to 12 nm. Absolute pJ values
//! are a substrate constant — every search arm shares them, so comparative
//! results (who wins, by what factor) are insensitive to the exact
//! numbers; see DESIGN.md §Substitutions.

/// Energy table in picojoules per 16-bit word access (or per op).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyTable {
    /// DRAM access, pJ/word.
    pub dram: f64,
    /// Global buffer access, pJ/word (grows with SRAM capacity).
    pub glb: f64,
    /// PE-local buffer access, pJ/word.
    pub pe_buf: f64,
    /// Register/operand latch at the MAC datapath, pJ/word.
    pub reg: f64,
    /// One multiply-accumulate, pJ.
    pub mac: f64,
    /// Network-on-chip, pJ/word/hop-level (GLB→PE distribution).
    pub noc: f64,
    /// Metadata-word processing (decode/intersect), pJ/word.
    pub metadata: f64,
}

/// SRAM read energy grows roughly with sqrt(capacity); anchor points from
/// Accelergy 45nm tables scaled by ~0.4x to 12 nm.
pub fn sram_energy_pj(capacity_bytes: u64) -> f64 {
    // 128 KiB ≈ 6 pJ/word reference point.
    let ref_cap = 128.0 * 1024.0;
    let ref_pj = 6.0;
    (ref_pj * ((capacity_bytes as f64) / ref_cap).sqrt()).clamp(0.6, 200.0)
}

impl EnergyTable {
    /// Build a 12 nm table for a given GLB/PE-buffer capacity.
    pub fn for_capacities(glb_bytes: u64, pe_buf_bytes: u64) -> EnergyTable {
        EnergyTable {
            dram: 200.0,
            glb: sram_energy_pj(glb_bytes),
            pe_buf: sram_energy_pj(pe_buf_bytes).min(2.5),
            reg: 0.08,
            mac: 1.0,
            noc: 0.35,
            metadata: 0.10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_energy_monotone_in_capacity() {
        assert!(sram_energy_pj(16 << 20) > sram_energy_pj(128 << 10));
        assert!(sram_energy_pj(64 << 20) > sram_energy_pj(16 << 20));
    }

    #[test]
    fn hierarchy_ordering() {
        // DRAM >> GLB > PE buffer > reg; MAC cheap relative to DRAM.
        let t = EnergyTable::for_capacities(128 << 10, 1 << 10);
        assert!(t.dram > 10.0 * t.glb);
        assert!(t.glb > t.pe_buf);
        assert!(t.pe_buf > t.reg);
        assert!(t.mac < t.glb);
    }

    #[test]
    fn clamped_extremes() {
        assert!(sram_energy_pj(16) >= 0.6);
        assert!(sram_energy_pj(u64::MAX / 2) <= 200.0);
    }
}
