//! Tiny command-line parser (the vendor set has no `clap`).
//!
//! Grammar: `sparsemap <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`. Callers validate
//! parsed names against their known sets with [`Args::reject_unknown`],
//! which points typos at the nearest valid option.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the subcommand.
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = it.next().unwrap().clone();
                    args.options.insert(stripped.to_string(), val);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_empty() {
                args.subcommand = tok.clone();
            } else {
                args.positional.push(tok.clone());
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    /// Reject any option or flag outside the known sets, suggesting the
    /// nearest valid name — so a typo like `--budjet 500` errors out
    /// instead of silently running with the default. Kind mismatches are
    /// rejected too: a known option that swallowed no value (`--budget`
    /// at the end of the line) or a known flag that swallowed one
    /// (`--json spec.json`) would otherwise silently fall back to the
    /// default, which is the exact failure this check exists to stop.
    pub fn reject_unknown(&self, known_opts: &[&str], known_flags: &[&str]) -> anyhow::Result<()> {
        for given in self.options.keys().map(String::as_str) {
            if known_opts.contains(&given) {
                continue;
            }
            if known_flags.contains(&given) {
                anyhow::bail!(
                    "'--{given}' is a flag and takes no value (it swallowed the next argument)"
                );
            }
            anyhow::bail!("unknown option '--{given}'{}", suggest(given, known_opts, known_flags));
        }
        for given in self.flags.iter().map(String::as_str) {
            if known_flags.contains(&given) {
                continue;
            }
            if known_opts.contains(&given) {
                anyhow::bail!("'--{given}' expects a value");
            }
            anyhow::bail!("unknown option '--{given}'{}", suggest(given, known_opts, known_flags));
        }
        Ok(())
    }
}

/// A " (did you mean ...)" hint naming the known option closest to
/// `given` by edit distance, if any is within a plausible typo radius.
fn suggest(given: &str, known_opts: &[&str], known_flags: &[&str]) -> String {
    nearest(given, known_opts.iter().chain(known_flags).copied())
        .map(|k| format!(" (did you mean '--{k}'?)"))
        .unwrap_or_default()
}

/// The candidate closest to `given` by edit distance, if any is within a
/// plausible typo radius (≤ 3 edits). Shared by the option parser above
/// and the optimizer registry's unknown-method/unknown-tunable errors.
pub fn nearest<'a>(given: &str, candidates: impl Iterator<Item = &'a str>) -> Option<&'a str> {
    candidates
        .map(|k| (levenshtein(given, k), k))
        .filter(|&(d, _)| d <= 3)
        .min_by_key(|&(d, _)| d)
        .map(|(_, k)| k)
}

/// Classic two-row Levenshtein edit distance.
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["search", "mm3", "extra"]);
        assert_eq!(a.subcommand, "search");
        assert_eq!(a.positional, vec!["mm3", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["search", "--budget=500", "--platform", "cloud"]);
        assert_eq!(a.opt("budget"), Some("500"));
        assert_eq!(a.opt("platform"), Some("cloud"));
        assert_eq!(a.opt_u64("budget", 0).unwrap(), 500);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["table4", "--summary"]);
        assert!(a.flag("summary"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_before_value_option() {
        // --quiet is a flag because the next token is another option.
        let a = parse(&["run", "--quiet", "--seed", "7"]);
        assert!(a.flag("quiet"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["run", "--seed", "x"]);
        assert!(a.opt_u64("seed", 0).is_err());
        assert!(a.opt_f64("seed", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["run"]);
        assert_eq!(a.opt_or("platform", "edge"), "edge");
        assert_eq!(a.opt_u64("budget", 20_000).unwrap(), 20_000);
    }

    #[test]
    fn unknown_option_rejected_with_suggestion() {
        let a = parse(&["search", "--budjet", "500"]);
        let err = a.reject_unknown(&["budget", "seed"], &["pjrt"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--budjet"), "{msg}");
        assert!(msg.contains("did you mean '--budget'"), "{msg}");
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["search", "--pjrtt"]);
        let err = a.reject_unknown(&["budget"], &["pjrt"]).unwrap_err();
        assert!(err.to_string().contains("did you mean '--pjrt'"));
    }

    #[test]
    fn known_args_pass() {
        let a = parse(&["search", "--budget", "500", "--pjrt"]);
        assert!(a.reject_unknown(&["budget"], &["pjrt"]).is_ok());
    }

    #[test]
    fn option_missing_its_value_rejected() {
        // `--budget` at end of line parses as a flag; it must not
        // silently fall back to the default budget.
        let a = parse(&["search", "--budget"]);
        let msg = a.reject_unknown(&["budget"], &["pjrt"]).unwrap_err().to_string();
        assert!(msg.contains("expects a value"), "{msg}");
    }

    #[test]
    fn flag_given_a_value_rejected() {
        // `--json spec.json` parses as an option and would silently eat
        // the positional; reject it loudly.
        let a = parse(&["run-spec", "--json", "spec.json"]);
        let msg = a.reject_unknown(&["budget"], &["json"]).unwrap_err().to_string();
        assert!(msg.contains("takes no value"), "{msg}");
    }

    #[test]
    fn wildly_wrong_name_gets_no_suggestion() {
        let a = parse(&["search", "--zzzzzzzzzz"]);
        let msg = a.reject_unknown(&["budget"], &[]).unwrap_err().to_string();
        assert!(!msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn nearest_candidate_within_radius() {
        assert_eq!(nearest("spasemap", ["sparsemap", "pso"].into_iter()), Some("sparsemap"));
        assert_eq!(nearest("zzzzzzzz", ["sparsemap", "pso"].into_iter()), None);
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("budget", "budget"), 0);
        assert_eq!(levenshtein("budjet", "budget"), 1);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
    }
}
