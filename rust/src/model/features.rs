//! FEATURE_SCHEMA_V1 — the Rust ⇄ JAX evaluator contract.
//!
//! [`extract`] turns a decoded design into a fixed-length numeric feature
//! vector. Everything *combinatorial* (loop-order reuse analysis, rank
//! enumeration, format storage models, S/G multipliers, fan-outs) is
//! resolved here; everything *arithmetic* (traffic scaling, energy sums,
//! bandwidth-bound latency, capacity checks, EDP) happens in the shared
//! cost formula — implemented twice, once in `model::cost` (f64, native)
//! and once in `python/compile/model.py` (f32, the AOT/PJRT hot path),
//! and cross-validated by tests.
//!
//! Any change here must bump [`SCHEMA_VERSION`] and be mirrored in
//! `python/compile/model.py`.

use crate::arch::{Boundary, Platform};
use crate::genome::{tensor_ranks, Design};
use crate::mapping::{loopnest, MapLevel, Mapping};
use crate::sparse::{control_overhead, effect, stack_storage_model, RankFormat, SgMechanism};
use crate::sparsity::effectual_frac;
use crate::workload::{Workload, NUM_TENSORS, TENSOR_P, TENSOR_Q, TENSOR_Z};

/// Schema version — serialized into `artifacts/meta.json` by the Python
/// AOT pipeline and asserted by the Rust runtime at load time.
pub const SCHEMA_VERSION: u32 = 1;

/// Feature vector length per design.
pub const NUM_FEATURES: usize = 48;
/// Platform vector length.
pub const NUM_PLATFORM_FEATURES: usize = 16;

// --- feature indices (keep in sync with python/compile/model.py) --------
pub const F_P_WORDS_B0: usize = 0;
pub const F_Q_WORDS_B0: usize = 1;
pub const F_Z_WORDS_B0: usize = 2;
pub const F_P_GLB_READS_B1: usize = 3;
pub const F_Q_GLB_READS_B1: usize = 4;
pub const F_Z_GLB_WORDS_B1: usize = 5;
pub const F_P_NOC_WORDS_B1: usize = 6;
pub const F_Q_NOC_WORDS_B1: usize = 7;
pub const F_Z_NOC_WORDS_B1: usize = 8;
pub const F_P_WORDS_B2: usize = 9;
pub const F_Q_WORDS_B2: usize = 10;
pub const F_Z_WORDS_B2: usize = 11;
pub const F_CR_P_B0: usize = 12;
pub const F_CR_Q_B0: usize = 13;
pub const F_CR_Z_B0: usize = 14;
pub const F_CR_P_B1: usize = 15;
pub const F_CR_Q_B1: usize = 16;
pub const F_CR_Z_B1: usize = 17;
pub const F_META_P_B0: usize = 18;
pub const F_META_Q_B0: usize = 19;
pub const F_META_Z_B0: usize = 20;
pub const F_META_P_B1: usize = 21;
pub const F_META_Q_B1: usize = 22;
pub const F_META_Z_B1: usize = 23;
pub const F_SG_P_ENERGY_B1: usize = 24;
pub const F_SG_Q_ENERGY_B1: usize = 25;
pub const F_SG_CYCLES_B1: usize = 26;
pub const F_SG_P_ENERGY_B2: usize = 27;
pub const F_SG_Q_ENERGY_B2: usize = 28;
pub const F_SG_CYCLES_B2: usize = 29;
pub const F_MAC_ENERGY_FRAC: usize = 30;
pub const F_COMPUTE_CYCLE_FRAC: usize = 31;
pub const F_TOTAL_OPS: usize = 32;
pub const F_ACTIVE_MACS: usize = 33;
pub const F_GLB_TILE_WORDS: usize = 34;
pub const F_PE_TILE_WORDS: usize = 35;
pub const F_STRUCT_VALID: usize = 36;
pub const F_CTRL_B1: usize = 37;
pub const F_CTRL_B2: usize = 38;
pub const F_CTRL_C: usize = 39;
pub const F_ACTIVE_PES: usize = 40;
pub const F_DENSITY_P: usize = 41;
pub const F_DENSITY_Q: usize = 42;
pub const F_DENSITY_Z: usize = 43;
// 44..48 reserved (zero).

/// Extracted feature vector (f64 precision; the runtime casts to f32).
pub type Features = [f64; NUM_FEATURES];

/// Compression statistics of a tensor's tile at a boundary, given the
/// tensor's (precomputed) materialized ranks and format stack.
fn tile_compression(
    w: &Workload,
    t: usize,
    ranks: &[crate::genome::RankId],
    tensor_formats: &[RankFormat],
    b: Boundary,
) -> (f64 /* cr */, f64 /* meta_frac */) {
    let inside = loopnest::levels_inside(b);
    let mut extents: Vec<u64> = Vec::new();
    let mut formats: Vec<RankFormat> = Vec::new();
    for (rank, fmt) in ranks.iter().zip(tensor_formats) {
        if inside.contains(&rank.level) {
            extents.push(rank.extent);
            formats.push(*fmt);
        }
    }
    let dense: f64 = extents.iter().map(|&e| e as f64).product();
    if extents.is_empty() || dense <= 1.0 {
        return (1.0, 0.0);
    }
    let (data, meta) = stack_storage_model(&extents, &formats, &w.tensors[t].density);
    ((data + meta) / dense, meta / dense)
}

// --- segment-pure stages -------------------------------------------------
//
// `extract` is decomposed into three stages with explicit inputs, one per
// natural genome segment, so the staged evaluation engine
// (`crate::search::engine`) can memoize each independently while the
// from-scratch path composes the *same* functions — parity by
// construction, not by duplication:
//
// * [`mapping_stage`]  — pure in the decoded mapping (permutation +
//   factor genes): all traffic features, tile sizes, sizing ratios,
//   fan-outs and the fan-out half of validity.
// * [`format_stage`]   — pure in (mapping ranks, one tensor's format
//   stack): compression ratios, metadata fractions, the
//   compressed/stack-validity bits.
// * [`assemble`]       — folds stage outputs plus the S/G mechanisms
//   (pure in the S/G genes) into the final vector. Allocation-free.

/// Mapping-derived feature components (everything a mapping determines
/// independent of formats and S/G genes). `Copy` so the engine can hand
/// it to workers and assembly without touching the heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MapFeats {
    /// Features `F_P_WORDS_B0..=F_Z_WORDS_B2` (indices `0..12`).
    traffic: [f64; 12],
    tile_b0: [f64; NUM_TENSORS],
    tile_b1: [f64; NUM_TENSORS],
    sizing_b0: [f64; NUM_TENSORS],
    sizing_b1: [f64; NUM_TENSORS],
    active_pes: f64,
    active_macs: f64,
    fanout_ok: bool,
}

/// Cached output of the mapping stage: the `Copy` feature components
/// plus the materialized per-tensor ranks the format stage consumes.
#[derive(Clone, Debug)]
pub struct MappingStage {
    pub feats: MapFeats,
    pub ranks: [Vec<crate::genome::RankId>; NUM_TENSORS],
}

/// Compute the mapping stage (pure in `m`; `plat` only feeds the
/// fan-out validity bit).
pub fn mapping_stage(m: &Mapping, w: &Workload, plat: &Platform) -> MappingStage {
    let mut tr = [0.0f64; 12];

    // Hot path: flatten the nest once and derive the three boundary loop
    // lists and per-tensor rank lists from it (profiling showed repeated
    // flatten/rank walks dominated extraction — see EXPERIMENTS.md §Perf).
    let flat = loopnest::flatten(m);
    let loops_b0 = loopnest::temporal_loops_above_from(&flat, Boundary::DramGlb);
    let loops_b1 = loopnest::temporal_loops_above_from(&flat, Boundary::GlbPe);
    let loops_b2 = loopnest::temporal_loops_above_from(&flat, Boundary::PeMac);
    let ranks: [Vec<crate::genome::RankId>; 3] = [
        tensor_ranks(m, w, 0),
        tensor_ranks(m, w, 1),
        tensor_ranks(m, w, 2),
    ];

    // --- boundary 0: DRAM -> GLB (dense-equivalent words) ---------------
    for (t, idx) in [(TENSOR_P, F_P_WORDS_B0), (TENSOR_Q, F_Q_WORDS_B0)] {
        tr[idx] = loopnest::tile_elems(m, w, t, Boundary::DramGlb)
            * loopnest::input_multiplicity_over(&loops_b0, w, t);
    }
    tr[F_Z_WORDS_B0] = loopnest::output_traffic_elems_over(
        &loops_b0,
        w,
        loopnest::tile_elems(m, w, TENSOR_Z, Boundary::DramGlb),
    );

    // --- boundary 1: GLB -> PEs over the NoC -----------------------------
    let pe_fanout = m.fanout(MapLevel::L2S) as f64;
    for (t, ridx, nidx) in [
        (TENSOR_P, F_P_GLB_READS_B1, F_P_NOC_WORDS_B1),
        (TENSOR_Q, F_Q_GLB_READS_B1, F_Q_NOC_WORDS_B1),
    ] {
        let tile = loopnest::tile_elems(m, w, t, Boundary::GlbPe);
        let mult = loopnest::input_multiplicity_over(&loops_b1, w, t);
        let distinct = loopnest::spatial_distinct(m, w, t, MapLevel::L2S) as f64;
        // GLB is read once per distinct tile (multicast on the NoC)...
        tr[ridx] = tile * mult * distinct;
        // ...but every PE receives its copy.
        tr[nidx] = tile * mult * pe_fanout;
    }
    {
        // Output at boundary 1: per-PE psum traffic plus cross-PE
        // reduction when contraction dims are spatial at L2_S.
        let tile = loopnest::tile_elems(m, w, TENSOR_Z, Boundary::GlbPe);
        let base = loopnest::output_traffic_elems_over(&loops_b1, w, tile);
        let distinct_z =
            loopnest::spatial_distinct(m, w, TENSOR_Z, MapLevel::L2S) as f64;
        let spatial_k = pe_fanout / distinct_z; // reduction width across PEs
        tr[F_Z_GLB_WORDS_B1] = base * distinct_z * spatial_k.max(1.0);
        tr[F_Z_NOC_WORDS_B1] = base * pe_fanout.max(1.0);
    }

    // --- boundary 2: PE buffer -> MACs -----------------------------------
    let mac_fanout = m.fanout(MapLevel::L3S) as f64;
    for (t, idx) in [(TENSOR_P, F_P_WORDS_B2), (TENSOR_Q, F_Q_WORDS_B2)] {
        let mult = loopnest::input_multiplicity_over(&loops_b2, w, t);
        let distinct = loopnest::spatial_distinct(m, w, t, MapLevel::L3S) as f64;
        tr[idx] = mult * distinct * pe_fanout;
    }
    {
        let base = loopnest::output_traffic_elems_over(&loops_b2, w, 1.0);
        let distinct_z =
            loopnest::spatial_distinct(m, w, TENSOR_Z, MapLevel::L3S) as f64;
        let spatial_k = mac_fanout / distinct_z;
        tr[F_Z_WORDS_B2] = base * distinct_z * spatial_k.max(1.0) * pe_fanout;
    }

    // --- tiles, sizing ratios, fan-outs ----------------------------------
    // Buffers are provisioned for the tail-quantile tile occupancy of
    // each tensor's sparsity pattern ([`DensityModel::sizing_ratio`]):
    // a mean-sized buffer under-provisions banded/skewed tensors whose
    // hot tiles are locally dense. Uniform models have ratio exactly 1.
    let mut tile_b0 = [0.0f64; NUM_TENSORS];
    let mut tile_b1 = [0.0f64; NUM_TENSORS];
    let mut sizing_b0 = [0.0f64; NUM_TENSORS];
    let mut sizing_b1 = [0.0f64; NUM_TENSORS];
    for t in 0..NUM_TENSORS {
        let dm = &w.tensors[t].density;
        tile_b0[t] = loopnest::tile_elems(m, w, t, Boundary::DramGlb);
        tile_b1[t] = loopnest::tile_elems(m, w, t, Boundary::GlbPe);
        sizing_b0[t] = dm.sizing_ratio(tile_b0[t]);
        sizing_b1[t] = dm.sizing_ratio(tile_b1[t]);
    }
    let fanout_ok = m.fanout(MapLevel::L2S) <= plat.total_pes()
        && m.fanout(MapLevel::L3S) <= plat.macs_per_pe;

    MappingStage {
        feats: MapFeats {
            traffic: tr,
            tile_b0,
            tile_b1,
            sizing_b0,
            sizing_b1,
            active_pes: pe_fanout.max(1.0),
            active_macs: (pe_fanout * mac_fanout).max(1.0),
            fanout_ok,
        },
        ranks,
    }
}

/// Format-stage output for one tensor: compression ratios and metadata
/// fractions at both storage boundaries plus the strategy-validity bits.
/// `Copy` — the engine caches it by (mapping, format-gene) key.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TensorCompression {
    /// Compression ratio at `[DramGlb, GlbPe]`.
    pub cr: [f64; 2],
    /// Metadata fraction at `[DramGlb, GlbPe]`.
    pub meta: [f64; 2],
    /// Any compressing rank in the stack (feeds the S/G driver check).
    pub compressed: bool,
    /// `compat::stack_ok` of the stack.
    pub stack_ok: bool,
}

/// Compute the format stage for tensor `t`: pure in (its materialized
/// ranks under the mapping, its assigned format stack).
pub fn format_stage(
    w: &Workload,
    t: usize,
    ranks: &[crate::genome::RankId],
    formats: &[RankFormat],
) -> TensorCompression {
    let (cr_b0, meta_b0) = tile_compression(w, t, ranks, formats, Boundary::DramGlb);
    let (cr_b1, meta_b1) = tile_compression(w, t, ranks, formats, Boundary::GlbPe);
    TensorCompression {
        cr: [cr_b0, cr_b1],
        meta: [meta_b0, meta_b1],
        compressed: formats.iter().any(|f| f.compressing()),
        stack_ok: crate::sparse::compat::stack_ok(formats),
    }
}

/// Per-workload constants consumed by [`assemble`] (precomputed once by
/// the engine; recomputed per call on the from-scratch path — same
/// functions, same inputs, identical bits).
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConsts {
    pub total_ops: f64,
    pub dp: f64,
    pub dq: f64,
    pub dz: f64,
    /// Intrinsic effectual-MAC fraction of the operand patterns
    /// (`sparsity::effectual_frac`; `dp*dq` for uniform models).
    pub min_compute_frac: f64,
}

impl WorkloadConsts {
    pub fn of(w: &Workload) -> WorkloadConsts {
        WorkloadConsts {
            total_ops: w.total_ops(),
            dp: w.density(TENSOR_P),
            dq: w.density(TENSOR_Q),
            dz: w.density(TENSOR_Z),
            min_compute_frac: effectual_frac(
                &w.tensors[TENSOR_P].density,
                &w.tensors[TENSOR_Q].density,
            ),
        }
    }
}

/// Fold stage outputs + S/G mechanisms into the final feature vector.
/// Pure arithmetic over `Copy` inputs: performs **zero heap allocation**
/// (the engine's steady-state invariant — see `rust/tests/alloc_steady_state.rs`).
pub fn assemble(
    c: &WorkloadConsts,
    mf: &MapFeats,
    comp: &[TensorCompression; NUM_TENSORS],
    sg: [SgMechanism; 3],
) -> Features {
    let mut f = [0.0f64; NUM_FEATURES];
    f[..12].copy_from_slice(&mf.traffic);

    // --- compression ratios and metadata fractions ----------------------
    for (t, cr0, cr1, me0, me1) in [
        (TENSOR_P, F_CR_P_B0, F_CR_P_B1, F_META_P_B0, F_META_P_B1),
        (TENSOR_Q, F_CR_Q_B0, F_CR_Q_B1, F_META_Q_B0, F_META_Q_B1),
        (TENSOR_Z, F_CR_Z_B0, F_CR_Z_B1, F_META_Z_B0, F_META_Z_B1),
    ] {
        f[cr0] = comp[t].cr[0];
        f[cr1] = comp[t].cr[1];
        f[me0] = comp[t].meta[0];
        f[me1] = comp[t].meta[1];
    }

    // --- S/G multipliers --------------------------------------------------
    let sg_l2 = effect(sg[0], c.dp, c.dq);
    let sg_l3 = effect(sg[1], c.dp, c.dq);
    let sg_c = effect(sg[2], c.dp, c.dq);
    f[F_SG_P_ENERGY_B1] = sg_l2.p_energy;
    f[F_SG_Q_ENERGY_B1] = sg_l2.q_energy;
    f[F_SG_CYCLES_B1] = sg_l2.cycles;
    f[F_SG_P_ENERGY_B2] = sg_l3.p_energy;
    f[F_SG_Q_ENERGY_B2] = sg_l3.q_energy;
    f[F_SG_CYCLES_B2] = sg_l3.cycles;
    f[F_MAC_ENERGY_FRAC] = sg_c.p_energy.min(sg_c.q_energy);
    // Skips anywhere shorten the effectual compute stream; floor at the
    // intrinsic effectual-MAC fraction of the operand patterns (for
    // uniform models exactly the legacy dp*dq).
    f[F_COMPUTE_CYCLE_FRAC] = (sg_l2.cycles * sg_l3.cycles * sg_c.cycles)
        .max(c.min_compute_frac)
        .min(1.0);
    f[F_CTRL_B1] = control_overhead(sg[0]);
    f[F_CTRL_B2] = control_overhead(sg[1]);
    f[F_CTRL_C] = control_overhead(sg[2]);

    // --- compute / occupancy / validity ----------------------------------
    f[F_TOTAL_OPS] = c.total_ops;
    f[F_ACTIVE_PES] = mf.active_pes;
    f[F_ACTIVE_MACS] = mf.active_macs;
    let mut glb_words = 0.0;
    let mut pe_words = 0.0;
    for t in 0..NUM_TENSORS {
        glb_words += mf.tile_b0[t] * comp[t].cr[0] * mf.sizing_b0[t];
        pe_words += mf.tile_b1[t] * comp[t].cr[1] * mf.sizing_b1[t];
    }
    f[F_GLB_TILE_WORDS] = glb_words;
    f[F_PE_TILE_WORDS] = pe_words;
    // Structural validity from the stage bits: fan-outs (mapping stage),
    // per-stack format rules (format stage), and the skip-needs-
    // compressed-driver rule (S/G genes + compressed bits). Equivalent to
    // `structural_problems(..).is_empty()` — the boolean twins are
    // equivalence-tested exhaustively in `sparse::compat`.
    let struct_valid = mf.fanout_ok
        && comp.iter().all(|tc| tc.stack_ok)
        && crate::sparse::compat::saf_ok(&sg, comp[0].compressed, comp[1].compressed);
    f[F_STRUCT_VALID] = if struct_valid { 1.0 } else { 0.0 };
    f[F_DENSITY_P] = c.dp;
    f[F_DENSITY_Q] = c.dq;
    f[F_DENSITY_Z] = c.dz;
    f
}

/// Extract FEATURE_SCHEMA_V1 for one design — composes the three
/// segment-pure stages, so this from-scratch path and the staged engine
/// are the same code.
pub fn extract(design: &Design, w: &Workload, plat: &Platform) -> Features {
    let ms = mapping_stage(&design.mapping, w, plat);
    let comp = [
        format_stage(w, TENSOR_P, &ms.ranks[TENSOR_P], &design.strategy.formats[TENSOR_P]),
        format_stage(w, TENSOR_Q, &ms.ranks[TENSOR_Q], &design.strategy.formats[TENSOR_Q]),
        format_stage(w, TENSOR_Z, &ms.ranks[TENSOR_Z], &design.strategy.formats[TENSOR_Z]),
    ];
    assemble(&WorkloadConsts::of(w), &ms.feats, &comp, design.strategy.sg)
}

/// Cast features to the f32 row consumed by the PJRT executable.
pub fn to_f32_row(f: &Features) -> Vec<f32> {
    f.iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{decode, GenomeSpec};
    use crate::util::rng::Pcg64;

    fn setup() -> (Workload, Platform, GenomeSpec) {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let p = Platform::edge();
        let s = GenomeSpec::for_workload(&w);
        (w, p, s)
    }

    /// All-ones mapping genes with *cleared* strategy segments (formats
    /// uncompressed, no S/G) — the dense reference genome.
    fn dense_genome(spec: &GenomeSpec) -> Vec<u32> {
        let mut g = vec![1u32; spec.len()];
        for i in spec.format_start..spec.len() {
            g[i] = 0;
        }
        g
    }

    #[test]
    fn features_finite_for_random_designs() {
        let (w, p, spec) = setup();
        let mut rng = Pcg64::seeded(9);
        for _ in 0..200 {
            let g = spec.random(&mut rng);
            let d = decode(&spec, &w, &g);
            let f = extract(&d, &w, &p);
            for (i, v) in f.iter().enumerate() {
                assert!(v.is_finite() && *v >= 0.0, "feature {i} = {v}");
            }
        }
    }

    #[test]
    fn dense_uncompressed_baseline() {
        let (w, p, spec) = setup();
        let g = dense_genome(&spec); // all tiling at L1_T, no formats
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        // No compression: all ratios 1, no metadata.
        for idx in [F_CR_P_B0, F_CR_Q_B0, F_CR_Z_B0] {
            assert_eq!(f[idx], 1.0);
        }
        for idx in [F_META_P_B0, F_META_Q_B0] {
            assert_eq!(f[idx], 0.0);
        }
        // No S/G: all multipliers 1.
        assert_eq!(f[F_SG_CYCLES_B1], 1.0);
        assert_eq!(f[F_MAC_ENERGY_FRAC], 1.0);
        assert_eq!(f[F_TOTAL_OPS], (16 * 32 * 16) as f64);
        assert_eq!(f[F_STRUCT_VALID], 1.0);
        assert_eq!(f[F_ACTIVE_MACS], 1.0); // no spatial mapping at all
    }

    #[test]
    fn compression_reduces_traffic_ratio_when_sparse() {
        let (w, p, spec) = setup();
        let mut g = dense_genome(&spec);
        // Tile M,K at L2_T so P has materialized ranks inside the GLB.
        for i in spec.factor_start..spec.format_start {
            g[i] = 2;
        }
        // P formats: bitmask everywhere.
        for s in 0..5 {
            g[spec.format_start + s] = 1;
        }
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        // P density 0.5, bitmask: cr < 1 (0.5 data + 1/16 metadata bits).
        assert!(f[F_CR_P_B0] < 1.0, "cr={}", f[F_CR_P_B0]);
        assert!(f[F_META_P_B0] > 0.0);
        // Q left uncompressed.
        assert_eq!(f[F_CR_Q_B0], 1.0);
    }

    #[test]
    fn spatial_mapping_populates_fanout() {
        let (w, p, spec) = setup();
        let mut g = dense_genome(&spec);
        // Put all of M (16 = 2^4) at L2_S: fanout 16.
        for i in 0..4 {
            g[spec.factor_start + i] = 3;
        }
        let d = decode(&spec, &w, &g);
        let f = extract(&d, &w, &p);
        assert_eq!(f[F_ACTIVE_PES], 16.0);
        assert_eq!(f[F_STRUCT_VALID], 1.0); // 16 <= 256 PEs
        // Q (K,N) has no M dim: broadcast to all 16 PEs, one GLB read.
        assert!(f[F_Q_NOC_WORDS_B1] >= 16.0 * f[F_Q_GLB_READS_B1] / 16.0);
        assert!(f[F_Q_GLB_READS_B1] * 16.0 == f[F_Q_NOC_WORDS_B1]);
    }

    #[test]
    fn structured_pattern_inflates_capacity_provisioning() {
        use crate::sparsity::DensityModel;
        use crate::workload::WorkloadKind;
        // Banded vs uniform P at the same mean density (4/32 = 0.125):
        // the banded tensor must provision buffers for locally-dense
        // band tiles, so its tile-words features grow.
        let mk = |model: DensityModel| {
            Workload::custom_models(
                "t",
                WorkloadKind::SpMM,
                vec![("M".into(), 16), ("K".into(), 32), ("N".into(), 16)],
                vec![
                    ("P".into(), vec![0, 1], Some(model)),
                    ("Q".into(), vec![1, 2], Some(DensityModel::uniform(0.25))),
                    ("Z".into(), vec![0, 2], None),
                ],
                vec![1],
            )
            .unwrap()
        };
        let w_uni = mk(DensityModel::uniform(0.125));
        let w_band = mk(DensityModel::banded(4, 32));
        let p = Platform::edge();
        let spec = GenomeSpec::for_workload(&w_uni);
        let mut g = dense_genome(&spec);
        for i in spec.factor_start..spec.format_start {
            g[i] = 2; // tile everything at L2_T so GLB tiles materialize
        }
        let f_uni = extract(&decode(&spec, &w_uni, &g), &w_uni, &p);
        let f_band = extract(&decode(&spec, &w_band, &g), &w_band, &p);
        // Small PE tiles sit inside a band row: P95 occupancy is the
        // dense band segment, far above the 12.5% mean.
        assert!(
            f_band[F_PE_TILE_WORDS] > f_uni[F_PE_TILE_WORDS],
            "banded {} vs uniform {}",
            f_band[F_PE_TILE_WORDS],
            f_uni[F_PE_TILE_WORDS]
        );
        // GLB tiles span whole rows, where banded occupancy concentrates
        // to the mean — provisioning matches the uniform case there.
        assert_eq!(f_band[F_GLB_TILE_WORDS], f_uni[F_GLB_TILE_WORDS]);
        // Mean-density features are identical — only provisioning and
        // compression statistics change.
        assert_eq!(f_band[F_DENSITY_P], f_uni[F_DENSITY_P]);
        for v in f_band.iter() {
            assert!(v.is_finite() && *v >= 0.0);
        }
    }

    #[test]
    fn stage_reuse_is_bitwise_identical_to_extract() {
        // The memoization contract: computing the mapping stage once and
        // assembling two different strategies against it must equal two
        // independent `extract` calls bit-for-bit.
        let (w, p, spec) = setup();
        let mut rng = Pcg64::seeded(23);
        let consts = WorkloadConsts::of(&w);
        for _ in 0..100 {
            let g1 = spec.random(&mut rng);
            // g2 shares g1's mapping segment, mutates formats + S/G.
            let mut g2 = spec.random(&mut rng);
            g2[..spec.format_start].copy_from_slice(spec.mapping_genes(&g1));
            let d1 = decode(&spec, &w, &g1);
            let d2 = decode(&spec, &w, &g2);
            assert_eq!(d1.mapping, d2.mapping);

            let ms = mapping_stage(&d1.mapping, &w, &p); // computed ONCE
            for (g, d) in [(&g1, &d1), (&g2, &d2)] {
                let comp = [
                    format_stage(&w, 0, &ms.ranks[0], &d.strategy.formats[0]),
                    format_stage(&w, 1, &ms.ranks[1], &d.strategy.formats[1]),
                    format_stage(&w, 2, &ms.ranks[2], &d.strategy.formats[2]),
                ];
                let staged = assemble(&consts, &ms.feats, &comp, d.strategy.sg);
                let scratch = extract(d, &w, &p);
                for i in 0..NUM_FEATURES {
                    assert_eq!(
                        staged[i].to_bits(),
                        scratch[i].to_bits(),
                        "feature {i} diverged for genome {g:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn schema_row_is_f32_sized() {
        let (w, p, spec) = setup();
        let d = decode(&spec, &w, &dense_genome(&spec));
        let row = to_f32_row(&extract(&d, &w, &p));
        assert_eq!(row.len(), NUM_FEATURES);
    }
}
