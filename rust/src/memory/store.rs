//! The design-memory store: append-only persistence + ANN lookup +
//! warm-start seed extraction, glued together behind one handle.
//!
//! A `MemoryStore` owns the on-disk record file (see [`super::record`])
//! and an in-RAM [`AnnIndex`] over the scenario embeddings of every
//! record. Appends go straight to disk (one length-framed record, no
//! rewrite) and into the index incrementally; `compact` is the only
//! operation that rewrites the file, and it does so atomically
//! (tmp + rename).

use super::embed::{dist2, scenario_embedding, scenario_tag, EMBED_DIM};
use super::index::AnnIndex;
use super::record::{header_bytes, salvage_file, MemRecord, MEMORY_SCHEMA};
use crate::arch::Platform;
use crate::genome::{Genome, GenomeSpec};
use crate::search::Outcome;
use crate::util::faults::{self, points};
use crate::util::json::Json;
use crate::workload::Workload;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Default record cap enforced by `memory compact` and the service's
/// startup rescan.
pub const DEFAULT_CAP: usize = 10_000;

/// A persisted, ANN-indexed store of elite designs keyed by scenario.
pub struct MemoryStore {
    path: PathBuf,
    records: Vec<MemRecord>,
    index: AnnIndex,
}

impl MemoryStore {
    /// Open (or lazily create) the store at `path`. A missing file is an
    /// empty store — the file itself is created on first append.
    ///
    /// A file with a **torn tail** (crash mid-append) is *salvaged*, not
    /// rejected: the intact record prefix is recovered, the damaged tail
    /// is quarantined verbatim into a `<path>.corrupt` sidecar, the main
    /// file is truncated back to its valid prefix, and the event is
    /// logged and counted (`sparsemap_memory_salvage_total`). Salvage
    /// never yields a partial record. Header-level corruption (bad
    /// magic, future version, foreign embed width) remains a hard error
    /// — under a wrong header nothing in the file can be trusted.
    pub fn open(path: impl Into<PathBuf>) -> Result<MemoryStore> {
        let path = path.into();
        let records = match fs::read(&path) {
            Ok(bytes) => {
                let salvage = salvage_file(&bytes)
                    .with_context(|| format!("reading memory store {}", path.display()))?;
                if let Some(damage) = &salvage.damage {
                    Self::quarantine_tail(&path, &bytes, salvage.valid_len, damage)?;
                }
                salvage.records
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => {
                return Err(anyhow::anyhow!("reading memory store {}: {e}", path.display()))
            }
        };
        let index = AnnIndex::build(&records.iter().map(|r| r.embed).collect::<Vec<_>>());
        Ok(MemoryStore { path, records, index })
    }

    /// Move the damaged tail of a salvaged store into its `.corrupt`
    /// sidecar and truncate the main file back to the valid prefix, so
    /// subsequent appends land after intact records only. The sidecar
    /// appends (a store damaged twice keeps both tails for forensics).
    fn quarantine_tail(path: &Path, bytes: &[u8], valid_len: usize, damage: &str) -> Result<()> {
        let tail = &bytes[valid_len..];
        let sidecar = PathBuf::from(format!("{}.corrupt", path.display()));
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&sidecar)
            .with_context(|| format!("opening quarantine sidecar {}", sidecar.display()))?;
        f.write_all(tail)?;
        f.sync_all()?;
        let main = fs::OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("truncating salvaged store {}", path.display()))?;
        main.set_len(valid_len as u64)?;
        main.sync_all()?;
        crate::obs::global().memory_salvages.inc();
        eprintln!(
            "warning: memory store {} salvaged — {damage}; {} damaged byte(s) quarantined to {}",
            path.display(),
            tail.len(),
            sidecar.display()
        );
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[MemRecord] {
        &self.records
    }

    /// Append one record: to disk first (header created if the file is
    /// new), fsynced before the in-RAM state sees it — an acknowledged
    /// append survives power loss. Disk errors leave the in-RAM state
    /// untouched. The record write passes through the `store-append`
    /// fault point; on a non-crash write error the file is truncated
    /// back to its pre-append length (best-effort) so a later retry
    /// appends after intact records. An injected *simulated-crash* torn
    /// write skips that cleanup — a real crash would too — leaving the
    /// torn tail for the next open to salvage.
    pub fn append(&mut self, rec: MemRecord) -> Result<()> {
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let fresh = !self.path.exists();
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening memory store {}", self.path.display()))?;
        if fresh {
            f.write_all(&header_bytes())?;
        }
        let len_before = f.metadata()?.len();
        if let Err(e) = faults::write_all_at(points::STORE_APPEND, &mut f, &rec.encode()) {
            if !faults::simulates_crash(&e) {
                let _ = f.set_len(len_before);
                let _ = f.sync_all();
            }
            return Err(e).with_context(|| {
                format!("appending to memory store {}", self.path.display())
            });
        }
        f.sync_all()
            .with_context(|| format!("syncing memory store {}", self.path.display()))?;
        self.index.insert(rec.embed);
        self.records.push(rec);
        Ok(())
    }

    /// Persist the elite design of a finished search, if it found one.
    /// Returns whether a record was written.
    pub fn remember(
        &mut self,
        w: &Workload,
        p: &Platform,
        method: &str,
        outcome: &Outcome,
        seed: u64,
    ) -> Result<bool> {
        let genome = match &outcome.best_genome {
            Some(g) if outcome.best_edp.is_finite() && !g.is_empty() => g.clone(),
            _ => return Ok(false),
        };
        self.append(MemRecord {
            tag: scenario_tag(w, p, method),
            best_edp: outcome.best_edp,
            evals: outcome.evals.min(u32::MAX as usize) as u32,
            valid_evals: outcome.valid_evals.min(u32::MAX as usize) as u32,
            seed,
            embed: scenario_embedding(w, p),
            genome,
        })?;
        Ok(true)
    }

    /// The `k` records nearest to `(w, p)` in scenario-embedding space,
    /// closest first. Deterministic for a fixed store.
    pub fn seed(&self, w: &Workload, p: &Platform, k: usize) -> Vec<&MemRecord> {
        let e = scenario_embedding(w, p);
        let hits: Vec<&MemRecord> =
            self.index.query(&e, k).into_iter().map(|id| &self.records[id as usize]).collect();
        crate::obs::global().memory_seeds.add(hits.len() as u64);
        hits
    }

    /// Turn nearest-neighbour records into genomes valid for `spec`:
    /// wrong-length genomes are dropped, out-of-range genes repaired
    /// in place, and duplicates (after repair) removed. Order follows
    /// the input (nearest first).
    pub fn validated_seed_genomes(records: &[&MemRecord], spec: &GenomeSpec) -> Vec<Genome> {
        let mut out: Vec<Genome> = Vec::new();
        for rec in records {
            if rec.genome.len() != spec.len() {
                continue;
            }
            let mut g = rec.genome.clone();
            if !spec.in_range(&g) {
                spec.repair(&mut g);
            }
            if !out.contains(&g) {
                out.push(g);
            }
        }
        out
    }

    /// Enforce `cap` via worst-cost eviction per scenario cluster:
    /// records sharing a tag form a cluster, and eviction repeatedly
    /// removes the worst-EDP record from the largest cluster (ties by
    /// tag order), so one hot scenario cannot crowd out the long tail.
    /// Rewrites the file atomically; returns the number evicted.
    pub fn compact(&mut self, cap: usize) -> Result<usize> {
        if self.records.len() <= cap {
            return Ok(0);
        }
        let evict_target = self.records.len() - cap;
        let mut dead = vec![false; self.records.len()];
        let mut clusters: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, r) in self.records.iter().enumerate() {
            clusters.entry(r.tag.as_str()).or_default().push(i);
        }
        // Within each cluster, order members worst (highest EDP) first
        // so eviction pops from the front.
        for members in clusters.values_mut() {
            members.sort_by(|&a, &b| {
                self.records[b]
                    .best_edp
                    .total_cmp(&self.records[a].best_edp)
                    .then(b.cmp(&a))
            });
        }
        let mut clusters: Vec<(&str, Vec<usize>)> = clusters.into_iter().collect();
        for _ in 0..evict_target {
            // Largest surviving cluster; ties broken by tag order.
            let (ci, _) = clusters
                .iter()
                .enumerate()
                .max_by_key(|(i, (_, m))| (m.len(), usize::MAX - i))
                .expect("non-empty cluster set while evicting");
            let victim = clusters[ci].1.remove(0);
            dead[victim] = true;
            if clusters[ci].1.is_empty() {
                clusters.remove(ci);
            }
        }
        let survivors: Vec<MemRecord> = self
            .records
            .iter()
            .zip(&dead)
            .filter(|(_, &d)| !d)
            .map(|(r, _)| r.clone())
            .collect();
        let evicted = self.records.len() - survivors.len();
        self.rewrite(&survivors)?;
        Ok(evicted)
    }

    /// Atomically and durably replace the file contents with `records`
    /// (tmp + fsync + rename + parent-dir fsync via
    /// [`crate::util::atomic_write`]).
    fn rewrite(&mut self, records: &[MemRecord]) -> Result<()> {
        let mut bytes = header_bytes().to_vec();
        for r in records {
            bytes.extend_from_slice(&r.encode());
        }
        crate::util::atomic_write(&self.path, &bytes)
            .with_context(|| format!("replacing {}", self.path.display()))?;
        self.records = records.to_vec();
        self.index = AnnIndex::build(&self.records.iter().map(|r| r.embed).collect::<Vec<_>>());
        Ok(())
    }

    /// Store statistics as JSON (for `sparsemap memory stats`).
    pub fn stats_json(&self) -> Json {
        let mut clusters: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
        for r in &self.records {
            let e = clusters.entry(r.tag.as_str()).or_insert((0, f64::INFINITY));
            e.0 += 1;
            if r.best_edp < e.1 {
                e.1 = r.best_edp;
            }
        }
        // Nearest-neighbour distance histogram over the stored
        // embeddings: how tightly the memory clusters in scenario space
        // (a spread-out store warm-starts poorly because every query
        // lands far from its seeds). Squared-L2 distances recorded at
        // 1e-9 resolution into the power-of-two-bucket histogram, so
        // the rendered quantiles come back in distance units.
        let nn = crate::obs::Histogram::new();
        for (i, r) in self.records.iter().enumerate() {
            let nearest = self
                .index
                .query(&r.embed, 2)
                .into_iter()
                .find(|&id| id as usize != i)
                .map(|id| dist2(&r.embed, &self.records[id as usize].embed));
            if let Some(d2) = nearest {
                nn.record((d2 * 1e9).round() as u64);
            }
        }
        Json::obj(vec![
            ("schema", Json::str(MEMORY_SCHEMA)),
            ("path", Json::str(&self.path.display().to_string())),
            ("records", Json::num(self.records.len() as f64)),
            ("scenarios", Json::num(clusters.len() as f64)),
            ("embed_dim", Json::num(EMBED_DIM as f64)),
            ("nn_dist", nn.snapshot().to_json(1e-9)),
            (
                "clusters",
                Json::Arr(
                    clusters
                        .into_iter()
                        .map(|(tag, (n, best))| {
                            Json::obj(vec![
                                ("tag", Json::str(tag)),
                                ("records", Json::num(n as f64)),
                                (
                                    "best_edp",
                                    if best.is_finite() { Json::num(best) } else { Json::Null },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Full record dump as JSON (for `sparsemap memory export`).
    pub fn export_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(MEMORY_SCHEMA)),
            ("records", Json::num(self.records.len() as f64)),
            (
                "entries",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("tag", Json::str(&r.tag)),
                                (
                                    "best_edp",
                                    if r.best_edp.is_finite() {
                                        Json::num(r.best_edp)
                                    } else {
                                        Json::Null
                                    },
                                ),
                                ("evals", Json::num(r.evals as f64)),
                                ("valid_evals", Json::num(r.valid_evals as f64)),
                                ("seed", Json::str(&r.seed.to_string())),
                                (
                                    "genome",
                                    Json::Arr(
                                        r.genome.iter().map(|&g| Json::num(g as f64)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::table3;

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("sparsemap_memstore_tests");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}_{}.bin", std::process::id()));
        let _ = fs::remove_file(&path);
        path
    }

    fn outcome_with(best: f64, genome: Vec<u32>) -> Outcome {
        Outcome {
            method: "es-std".into(),
            workload: "mm1".into(),
            platform: "mobile".into(),
            evals: 100,
            valid_evals: 90,
            cache_hits: 0,
            interned: 0,
            stage_hits: 0,
            best_edp: best,
            best_genome: Some(genome),
            curve: vec![],
            population_mean_curve: vec![],
            members: vec![],
            memory_hits: 0,
            seeded_from: vec![],
            model_calls: 0,
            batches: 0,
        }
    }

    #[test]
    fn open_append_reopen_round_trips() {
        let path = tmp_store("roundtrip");
        let w = table3::by_id("mm1").unwrap();
        let p = Platform::mobile();
        let spec = GenomeSpec::for_workload(&w);
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let genome = spec.random(&mut rng);
        {
            let mut st = MemoryStore::open(&path).unwrap();
            assert!(st.is_empty());
            assert!(st
                .remember(&w, &p, "es-std", &outcome_with(123.0, genome.clone()), 9)
                .unwrap());
            // An outcome with no valid best is a no-op.
            let mut none = outcome_with(f64::INFINITY, vec![]);
            none.best_genome = None;
            assert!(!st.remember(&w, &p, "es-std", &none, 9).unwrap());
            assert_eq!(st.len(), 1);
        }
        let st = MemoryStore::open(&path).unwrap();
        assert_eq!(st.len(), 1);
        assert_eq!(st.records()[0].genome, genome);
        assert_eq!(st.records()[0].tag, "mm1@mobile#es-std");
        assert_eq!(st.records()[0].best_edp.to_bits(), 123.0f64.to_bits());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn seed_returns_nearest_scenarios_and_validates() {
        let path = tmp_store("seed");
        let mut st = MemoryStore::open(&path).unwrap();
        let p = Platform::mobile();
        let near = table3::by_id("mm1").unwrap();
        let far = table3::by_id("mm10").unwrap();
        let spec_near = GenomeSpec::for_workload(&near);
        let mut rng = crate::util::rng::Pcg64::seeded(1);
        let g_near = spec_near.random(&mut rng);
        st.remember(&near, &p, "es-std", &outcome_with(10.0, g_near.clone()), 1).unwrap();
        let spec_far = GenomeSpec::for_workload(&far);
        let g_far = spec_far.random(&mut rng);
        st.remember(&far, &p, "es-std", &outcome_with(20.0, g_far.clone()), 1).unwrap();

        // Query with a slightly perturbed mm1: the mm1 record ranks first.
        let query = Workload::spmm("mm1b", 124, 124, 124, 0.75, 0.80);
        let hits = st.seed(&query, &p, 2);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].tag, "mm1@mobile#es-std");

        // Validation drops genomes whose length doesn't fit the spec and
        // repairs out-of-range genes.
        let spec_q = GenomeSpec::for_workload(&query);
        assert_eq!(spec_q.len(), spec_near.len(), "same dims, same genome layout");
        let genomes = MemoryStore::validated_seed_genomes(&hits, &spec_q);
        assert!(!genomes.is_empty());
        assert!(genomes.iter().all(|g| spec_q.in_range(g)));
        let _ = fs::remove_file(&path);
    }

    // Crafts the torn file with direct byte surgery rather than the
    // `store-append` fault point: unit tests share the process-global
    // fault plan with parallel siblings, so only the serialized
    // integration suite (`tests/faults.rs`) arms it.
    #[test]
    fn open_salvages_a_torn_tail_and_quarantines_it() {
        let path = tmp_store("salvage");
        let w = table3::by_id("mm1").unwrap();
        let p = Platform::mobile();
        let spec = GenomeSpec::for_workload(&w);
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        let g1 = spec.random(&mut rng);
        {
            let mut st = MemoryStore::open(&path).unwrap();
            st.remember(&w, &p, "es-std", &outcome_with(1.0, g1.clone()), 1).unwrap();
            st.remember(&w, &p, "es-std", &outcome_with(2.0, spec.random(&mut rng)), 2).unwrap();
        }
        // Tear the file mid-way through the second record.
        let full = fs::read(&path).unwrap();
        let first_end = {
            let s = crate::memory::salvage_file(&full).unwrap();
            assert!(s.damage.is_none());
            let mut bytes = crate::memory::header_bytes().to_vec();
            bytes.extend_from_slice(&s.records[0].encode());
            bytes.len()
        };
        let cut = first_end + 20;
        fs::write(&path, &full[..cut]).unwrap();

        let mut st = MemoryStore::open(&path).unwrap();
        assert_eq!(st.len(), 1, "intact prefix recovered");
        assert_eq!(st.records()[0].genome, g1);
        let sidecar = PathBuf::from(format!("{}.corrupt", path.display()));
        assert_eq!(
            fs::read(&sidecar).unwrap(),
            &full[first_end..cut],
            "damaged tail quarantined verbatim"
        );
        assert_eq!(
            fs::metadata(&path).unwrap().len() as usize,
            first_end,
            "main file truncated to the valid prefix"
        );
        // The store keeps working: append + clean reopen, no sidecar growth.
        st.remember(&w, &p, "es-std", &outcome_with(3.0, spec.random(&mut rng)), 3).unwrap();
        let st = MemoryStore::open(&path).unwrap();
        assert_eq!(st.len(), 2);
        assert_eq!(fs::read(&sidecar).unwrap().len(), cut - first_end);
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(&sidecar);
    }

    #[test]
    fn compact_evicts_worst_per_largest_cluster() {
        let path = tmp_store("compact");
        let mut st = MemoryStore::open(&path).unwrap();
        let p = Platform::mobile();
        let hot = table3::by_id("mm1").unwrap();
        let cold = table3::by_id("mm10").unwrap();
        let spec = GenomeSpec::for_workload(&hot);
        let spec_cold = GenomeSpec::for_workload(&cold);
        let mut rng = crate::util::rng::Pcg64::seeded(2);
        for i in 0..5 {
            let g = spec.random(&mut rng);
            st.remember(&hot, &p, "es-std", &outcome_with(100.0 + i as f64, g), i).unwrap();
        }
        let g = spec_cold.random(&mut rng);
        st.remember(&cold, &p, "es-std", &outcome_with(999.0, g), 7).unwrap();
        assert_eq!(st.len(), 6);

        let evicted = st.compact(3).unwrap();
        assert_eq!(evicted, 3);
        assert_eq!(st.len(), 3);
        // The cold scenario survives (evictions hit the largest cluster),
        // and within the hot cluster the best records survive.
        assert!(st.records().iter().any(|r| r.tag == "mm10@mobile#es-std"));
        let hot_best: Vec<f64> = st
            .records()
            .iter()
            .filter(|r| r.tag == "mm1@mobile#es-std")
            .map(|r| r.best_edp)
            .collect();
        assert_eq!(hot_best.len(), 2);
        assert!(hot_best.iter().all(|&e| e <= 101.0), "kept {hot_best:?}");
        // No-op below the cap; store still loads after the rewrite.
        assert_eq!(st.compact(10).unwrap(), 0);
        assert_eq!(MemoryStore::open(&path).unwrap().len(), 3);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn stats_and_export_shapes() {
        let path = tmp_store("stats");
        let mut st = MemoryStore::open(&path).unwrap();
        let w = table3::by_id("mm1").unwrap();
        let p = Platform::mobile();
        let spec = GenomeSpec::for_workload(&w);
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        st.remember(&w, &p, "es-std", &outcome_with(5.0, spec.random(&mut rng)), 4).unwrap();
        let stats = st.stats_json().dumps();
        assert!(stats.contains("\"sparsemap.memory.v1\""));
        assert!(stats.contains("\"scenarios\":1") || stats.contains("\"scenarios\": 1"));
        let export = st.export_json();
        assert_eq!(export.get("entries").and_then(Json::as_arr).unwrap().len(), 1);
        // A single record has no neighbour: the NN histogram is empty.
        let nn = st.stats_json().get("nn_dist").cloned().unwrap();
        assert_eq!(nn.get("count").and_then(Json::as_u64), Some(0), "{}", nn.pretty());

        // With more records each one has a nearest neighbour, and the
        // two mm1 records sit closer to each other than to mm10.
        let w2 = table3::by_id("mm10").unwrap();
        let spec2 = GenomeSpec::for_workload(&w2);
        st.remember(&w, &p, "es-std", &outcome_with(6.0, spec.random(&mut rng)), 5).unwrap();
        st.remember(&w2, &p, "es-std", &outcome_with(7.0, spec2.random(&mut rng)), 6).unwrap();
        let nn = st.stats_json().get("nn_dist").cloned().unwrap();
        assert_eq!(nn.get("count").and_then(Json::as_u64), Some(3), "{}", nn.pretty());
        // The two identical-scenario records are distance 0 apart, so
        // the median bucket bound sits at the histogram floor while the
        // odd-one-out pushes the max up.
        let p50 = nn.get("p50").and_then(Json::as_f64).unwrap();
        let max = nn.get("max").and_then(Json::as_f64).unwrap();
        assert!(p50 <= 1e-8, "identical scenarios are zero distance apart: {p50}");
        assert!(max > p50, "mm10 is far from the mm1 pair: {max}");
        let _ = fs::remove_file(&path);
    }
}
