//! Quickstart: search one workload on one platform through the
//! `sparsemap::api` front door, stream progress, and print the winning
//! accelerator design.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparsemap::api::SearchRequest;
use sparsemap::genome::{decode, describe, GenomeSpec};
use sparsemap::search::{Progress, SearchControl};

fn main() -> anyhow::Result<()> {
    // 1. Describe the arm: a DeepBench bibd-class SpMM on the cloud
    //    platform, 10k-sample budget. Swap `workload_named` for
    //    `.workload(Workload::custom(..)?)` to search any contraction.
    let request = SearchRequest::new()
        .workload_named("mm3")
        .platform_named("cloud")
        .budget(10_000)
        .seed(42);

    // 2. Validate into a session and run with a progress observer.
    let session = request.build()?;
    let workload = session.workload().clone();
    println!(
        "searching {} ({}) on {} ...",
        workload.id,
        workload.kind.as_str(),
        session.platform().name
    );
    let report = session.run_observed(Box::new(|p: &Progress| {
        if p.batches % 25 == 0 {
            println!(
                "  gen ~{:3}: {:5}/{} evals, best EDP {:.4e}",
                p.batches, p.evals, p.budget, p.best_edp
            );
        }
        SearchControl::Continue
    }))?;

    // 3. Report.
    let outcome = &report.outcome;
    println!(
        "best EDP: {:.4e} pJ*cycles  ({} evals, {:.1}% of explored points valid)",
        outcome.best_edp,
        outcome.evals,
        100.0 * outcome.valid_ratio()
    );
    let genome = outcome.best_genome.clone().expect("no valid design found");
    let spec = GenomeSpec::for_workload(&workload);
    let design = decode(&spec, &workload, &genome);
    println!("--- winning design ---\n{}", describe(&design, &workload));

    println!("convergence (evals -> best EDP):");
    for (e, v) in outcome.curve.iter().take(12) {
        println!("  {:>6} -> {:.4e}", e, v);
    }
    Ok(())
}
