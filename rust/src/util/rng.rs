//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set does not ship the `rand` crate, so SparseMap
//! carries its own small PRNG: PCG64 (O'Neill, "PCG: A Family of Simple
//! Fast Space-Efficient Statistically Good Algorithms for Random Number
//! Generation"). Every stochastic component in the crate (search
//! algorithms, workload samplers, property tests) takes an explicit
//! `&mut Pcg64` so that runs are reproducible from a single seed.

/// PCG-XSL-RR 128/64 generator.
///
/// 128-bit LCG state, 64-bit output via xorshift-low + random rotation.
/// Passes BigCrush; more than adequate for stochastic search.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids give statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | (stream as u128)) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Split off an independent child generator (used to hand one RNG per
    /// worker thread while keeping the parent deterministic).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection to
    /// avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as u32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Expose the raw generator state for checkpointing. Together with
    /// [`Pcg64::from_parts`] this round-trips the generator exactly: the
    /// restored instance produces the identical output stream.
    pub fn to_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from state captured by [`Pcg64::to_parts`].
    pub fn from_parts(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg64::seeded(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(17);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8);
    }

    #[test]
    fn parts_round_trip() {
        let mut a = Pcg64::seeded(23);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.to_parts();
        let mut b = Pcg64::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_independent() {
        let mut a = Pcg64::seeded(21);
        let mut c1 = a.split();
        let mut c2 = a.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
