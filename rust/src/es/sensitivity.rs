//! Monte-Carlo high-sensitivity gene calibration (§IV.D, Eq. 2–5).
//!
//! For each gene v: fix all other genes to a random combination, sweep v
//! over Monte-Carlo samples of its range, evaluate, and average the
//! normalized EDP variation ratio between random pairs of valid samples
//! (Eq. 2). Repeat over `trials` random contexts and average (Eq. 3).
//! Genes above the 3/4-quantile threshold (Eq. 4/5) are *high-sensitivity*.

use crate::genome::Genome;
use crate::search::EvalContext;
use crate::util::rng::Pcg64;

/// Calibration output.
#[derive(Clone, Debug)]
pub struct Sensitivity {
    /// Per-gene sensitivity S(v).
    pub scores: Vec<f64>,
    /// Indices of high-sensitivity genes (Eq. 4).
    pub high: Vec<usize>,
    /// Indices of low-sensitivity genes (Eq. 5).
    pub low: Vec<usize>,
    /// Valid genomes encountered during calibration — reused by the
    /// hypercube initializer for low-sensitivity gene assignments.
    pub valid_pool: Vec<Genome>,
    /// Evaluations spent (the <10%-of-budget overhead claim, E8).
    pub evals_spent: usize,
}

/// Calibration hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct CalibConfig {
    /// Monte-Carlo samples of each gene per trial.
    pub samples_per_gene: usize,
    /// Independent random contexts per gene (the paper's I).
    pub trials: usize,
    /// Random EDP-pairs drawn per trial for the variation ratio.
    pub pairs: usize,
    /// Hard cap on evaluations spent (0 = unlimited). SparseMap sets
    /// this to ~10% of the search budget — the paper's E8 overhead claim.
    pub max_evals: usize,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { samples_per_gene: 6, trials: 3, pairs: 8, max_evals: 0 }
    }
}

/// What one [`CalibMachine::step`] call ended with.
pub enum CalibStep {
    /// Every gene has been visited (or capped): calibration is complete.
    Done(Sensitivity),
    /// The context asked to pause (budget/fence exhausted or suspension
    /// requested). Call `step` again on a refreshed context to continue,
    /// or [`CalibMachine::force_finish`] to settle for the genes visited
    /// so far.
    Paused,
}

/// Resumable calibration state machine.
///
/// [`calibrate`] drives it to completion in one call; the ES optimizer
/// keeps one alive across suspend/resume cycles. The machine pauses only
/// at the top of the per-gene loop, where nothing of the pending gene has
/// consumed RNG or budget yet, so a paused-and-resumed calibration
/// replays bit-identically to an uninterrupted one.
pub struct CalibMachine {
    pub(crate) cfg: CalibConfig,
    /// Absolute `ctx.used()` at machine creation (for the eval cap and
    /// `evals_spent`); still valid after a restore because the eval state
    /// snapshot restores the same counter.
    pub(crate) start_evals: usize,
    /// Random gene visiting order (so a budget cap doesn't systematically
    /// starve the trailing strategy genes).
    pub(crate) gene_order: Vec<usize>,
    /// Next index into `gene_order`.
    pub(crate) pos: usize,
    pub(crate) scores: Vec<f64>,
    pub(crate) valid_pool: Vec<Genome>,
}

impl CalibMachine {
    pub fn new(ctx: &EvalContext, cfg: CalibConfig, rng: &mut Pcg64) -> CalibMachine {
        let n = ctx.spec.len();
        let mut gene_order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut gene_order);
        CalibMachine {
            cfg,
            start_evals: ctx.used(),
            gene_order,
            pos: 0,
            scores: vec![0.0f64; n],
            valid_pool: Vec::new(),
        }
    }

    /// Advance until done or the context wants to pause.
    pub fn step(&mut self, ctx: &mut EvalContext, rng: &mut Pcg64) -> CalibStep {
        let spec = ctx.spec.clone();
        while self.pos < self.gene_order.len() {
            if ctx.should_pause() {
                return CalibStep::Paused;
            }
            let gene = self.gene_order[self.pos];
            self.pos += 1;
            let range = spec.ranges[gene];
            if range.width() <= 1 {
                continue; // constant gene: no sensitivity
            }
            let mut trial_scores = Vec::with_capacity(self.cfg.trials);
            for _ in 0..self.cfg.trials {
                let over_cap = self.cfg.max_evals > 0
                    && ctx.used() - self.start_evals >= self.cfg.max_evals;
                if ctx.exhausted() || over_cap {
                    break;
                }
                // Fix the other genes to one random context.
                let context_genome = spec.random(rng);
                // Monte-Carlo sample of this gene's values (dedup).
                let k = (self.cfg.samples_per_gene as u32).min(range.width()) as usize;
                let mut values: Vec<u32> =
                    if (range.width() as usize) <= self.cfg.samples_per_gene {
                        (range.lo..=range.hi).collect()
                    } else {
                        let mut vs: Vec<u32> = (0..k).map(|_| range.sample(rng)).collect();
                        vs.sort_unstable();
                        vs.dedup();
                        vs
                    };
                if values.len() < 2 {
                    continue;
                }
                let genomes: Vec<Genome> = values
                    .iter()
                    .map(|&v| {
                        let mut g = context_genome.clone();
                        g[gene] = v;
                        g
                    })
                    .collect();
                let results = ctx.eval_batch(&genomes);
                // Valid (value, EDP) pairs — dead points are excluded (V_d).
                let mut vd: Vec<(f64, f64)> = Vec::new();
                for ((v, g), r) in values.iter().zip(&genomes).zip(&results) {
                    if r.valid {
                        vd.push((*v as f64, r.edp));
                        self.valid_pool.push(g.clone());
                    }
                }
                values.clear();
                if vd.len() < 2 {
                    continue;
                }
                // Average normalized EDP variation ratio over random pairs.
                let mut acc = 0.0;
                let mut cnt = 0;
                for _ in 0..self.cfg.pairs {
                    let i = rng.index(vd.len());
                    let mut j = rng.index(vd.len());
                    if i == j {
                        j = (j + 1) % vd.len();
                    }
                    let (v1, e1) = vd[i];
                    let (v2, e2) = vd[j];
                    if (v1 - v2).abs() < 1e-12 {
                        continue;
                    }
                    acc += (e1 - e2).abs() / ((v1 - v2).abs() * e1.min(e2));
                    cnt += 1;
                }
                if cnt > 0 {
                    trial_scores.push(acc / cnt as f64);
                }
            }
            if !trial_scores.is_empty() {
                self.scores[gene] =
                    trial_scores.iter().sum::<f64>() / trial_scores.len() as f64;
            }
        }
        CalibStep::Done(self.force_finish(ctx))
    }

    /// Settle with the genes visited so far (unvisited genes keep score
    /// 0) — what a plain budget-exhausted run gets, since exhausted
    /// trials are skipped anyway.
    pub fn force_finish(&self, ctx: &EvalContext) -> Sensitivity {
        let (high, low) = split_by_threshold(&self.scores);
        Sensitivity {
            scores: self.scores.clone(),
            high,
            low,
            valid_pool: self.valid_pool.clone(),
            evals_spent: ctx.used() - self.start_evals,
        }
    }
}

/// Run the calibration to completion. Consumes budget from `ctx`.
pub fn calibrate(ctx: &mut EvalContext, cfg: CalibConfig, rng: &mut Pcg64) -> Sensitivity {
    let mut m = CalibMachine::new(ctx, cfg, rng);
    match m.step(ctx, rng) {
        CalibStep::Done(s) => s,
        // Only reachable when the budget ran out mid-calibration; the
        // remaining genes would have been skipped as no-ops anyway.
        CalibStep::Paused => m.force_finish(ctx),
    }
}

/// Eq. 4/5: high = { v : S(v) > 3/4·(Smax − Smin) + Smin }.
pub fn split_by_threshold(scores: &[f64]) -> (Vec<usize>, Vec<usize>) {
    let smax = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let smin = scores.iter().copied().fold(f64::INFINITY, f64::min);
    if !smax.is_finite() || !smin.is_finite() || (smax - smin) < 1e-30 {
        // Degenerate: treat everything as low-sensitivity.
        return (Vec::new(), (0..scores.len()).collect());
    }
    let thr = 0.75 * (smax - smin) + smin;
    let mut high = Vec::new();
    let mut low = Vec::new();
    for (i, &s) in scores.iter().enumerate() {
        if s > thr {
            high.push(i);
        } else {
            low.push(i);
        }
    }
    (high, low)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::{Backend, EvalContext};
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        EvalContext::new(Backend::native(w, Platform::edge()), budget)
    }

    #[test]
    fn threshold_split() {
        let scores = vec![0.0, 1.0, 10.0, 7.4, 7.6];
        let (high, low) = split_by_threshold(&scores);
        assert_eq!(high, vec![2, 4]); // > 7.5
        assert_eq!(low, vec![0, 1, 3]);
    }

    #[test]
    fn degenerate_scores_all_low() {
        let (high, low) = split_by_threshold(&[0.5; 4]);
        assert!(high.is_empty());
        assert_eq!(low.len(), 4);
    }

    #[test]
    fn calibration_produces_partition_and_pool() {
        let mut c = ctx(6_000);
        let mut rng = Pcg64::seeded(11);
        let s = calibrate(&mut c, CalibConfig::default(), &mut rng);
        assert_eq!(s.scores.len(), c.spec.len());
        assert_eq!(s.high.len() + s.low.len(), c.spec.len());
        assert!(!s.valid_pool.is_empty(), "no valid points found during calibration");
        assert!(s.evals_spent > 0);
        // Sensitivities must be finite and non-negative.
        assert!(s.scores.iter().all(|x| x.is_finite() && *x >= 0.0));
    }

    #[test]
    fn respects_budget() {
        let mut c = ctx(50);
        let mut rng = Pcg64::seeded(12);
        let s = calibrate(&mut c, CalibConfig::default(), &mut rng);
        assert!(s.evals_spent <= 50);
        assert!(c.used() <= 50);
    }
}
