//! E1 / Fig. 2 — motivation study: no single (mapping, sparse strategy)
//! pair wins across sparsity levels.
//!
//! We evaluate four hand-built designs — {output-stationary, input-
//! stationary} × {CSR-like UOP-CP, RLE} — on a fixed GEMM while sweeping
//! operand density, and report normalized latency and energy. The paper's
//! qualitative claim to reproduce: the winner changes with sparsity and
//! with the mapping.

use super::{write_csv, ExpConfig};
use crate::arch::Platform;
use crate::genome::{decode, Design, GenomeSpec};
use crate::mapping::permutation;
use crate::model::NativeEvaluator;
use crate::util::table::Table;
use crate::workload::Workload;

/// The four design arms of the figure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arm {
    OsCsr,
    OsRle,
    IsCsr,
    IsRle,
}

impl Arm {
    pub const ALL: [Arm; 4] = [Arm::OsCsr, Arm::OsRle, Arm::IsCsr, Arm::IsRle];

    pub fn name(self) -> &'static str {
        match self {
            Arm::OsCsr => "OS+CSR",
            Arm::OsRle => "OS+RLE",
            Arm::IsCsr => "IS+CSR",
            Arm::IsRle => "IS+RLE",
        }
    }

    fn output_stationary(self) -> bool {
        matches!(self, Arm::OsCsr | Arm::OsRle)
    }

    fn csr(self) -> bool {
        matches!(self, Arm::OsCsr | Arm::IsCsr)
    }
}

/// Build the arm's design for the given workload. The mapping comes from
/// genes; the format stacks are constructed directly on the materialized
/// ranks (this is a hand-crafted motivation design, not a genome search).
fn build_design(spec: &GenomeSpec, w: &Workload, arm: Arm) -> Design {
    use crate::genome::tensor_ranks;
    use crate::sparse::{RankFormat, SgMechanism};

    let mut g = vec![1u32; spec.len()];
    for i in spec.format_start..spec.len() {
        g[i] = 0;
    }
    // Mapping: per dim, one spatial factor at L2_S, two temporal factors
    // at L1_T (so the L1 permutation — the stationarity choice — actually
    // drives DRAM traffic), the rest at L3_T.
    let mut fi = spec.factor_start;
    for dspec in &w.dims {
        for (idx, _) in dspec.factors.iter().enumerate() {
            g[fi] = match idx {
                0 => 3,     // L2_S
                1 | 2 => 1, // L1_T
                _ => 4,     // L3_T
            };
            fi += 1;
        }
    }
    // L1 loop order: OS = (M, N, K) keeps the output tile stationary in
    // the GLB (trailing K is irrelevant to Z); IS = (K, M, N) keeps the
    // input P stationary (trailing N is irrelevant to P).
    let os = permutation::encode(&[0, 2, 1]) as u32; // M, N, K
    let is = permutation::encode(&[1, 0, 2]) as u32; // K, M, N
    let code = if arm.output_stationary() { os } else { is };
    g[0] = code;
    g[1] = code;
    let mut design = decode(spec, w, &g);

    // Formats: CSR-like = UOP at the outermost rank, CP below; RLE arm =
    // RLE at every rank. Z stays uncompressed (psum traffic).
    for t in 0..2 {
        let ranks = tensor_ranks(&design.mapping, w, t);
        design.strategy.formats[t] = ranks
            .iter()
            .enumerate()
            .map(|(i, _)| {
                if arm.csr() {
                    if i == 0 {
                        RankFormat::UncompressedOffsetPair
                    } else {
                        RankFormat::CoordinatePayload
                    }
                } else {
                    RankFormat::Rle
                }
            })
            .collect();
    }
    // S/G: skip at the GLB driven by Q plus a compute gate — shared by
    // all arms (the figure varies mapping/format only).
    design.strategy.sg = [SgMechanism::SkipPfromQ, SgMechanism::None, SgMechanism::GateBoth];
    design
}

/// One sweep row.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub density: f64,
    pub arm: &'static str,
    pub latency: f64,
    pub energy: f64,
    pub valid: bool,
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<String> {
    let densities = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9];
    let mut rows: Vec<Fig2Row> = Vec::new();

    for &d in &densities {
        let w = Workload::spmm("fig2", 256, 256, 256, d, d);
        let plat = Platform::mobile();
        let ev = NativeEvaluator::new(w.clone(), plat);
        let spec = GenomeSpec::for_workload(&w);
        for arm in Arm::ALL {
            let design = build_design(&spec, &w, arm);
            let cb = ev.breakdown(&design);
            rows.push(Fig2Row {
                density: d,
                arm: arm.name(),
                latency: cb.cycles,
                energy: cb.energy_pj,
                valid: cb.valid > 0.5,
            });
        }
    }

    // Normalize per density (the figure normalizes to the worst arm).
    let mut table = Table::new(&["density", "arm", "norm_latency", "norm_energy", "winner_edp"]);
    let mut csv = String::from("density,arm,latency_cycles,energy_pj,norm_latency,norm_energy\n");
    for &d in &densities {
        let group: Vec<&Fig2Row> =
            rows.iter().filter(|r| r.density == d && r.valid).collect();
        if group.is_empty() {
            continue;
        }
        let max_lat = group.iter().map(|r| r.latency).fold(0.0, f64::max);
        let max_en = group.iter().map(|r| r.energy).fold(0.0, f64::max);
        let winner = group
            .iter()
            .min_by(|a, b| {
                (a.latency * a.energy).partial_cmp(&(b.latency * b.energy)).unwrap()
            })
            .unwrap()
            .arm;
        for r in &group {
            table.row(vec![
                format!("{:.2}", d),
                r.arm.to_string(),
                format!("{:.3}", r.latency / max_lat),
                format!("{:.3}", r.energy / max_en),
                if r.arm == winner { "*".into() } else { String::new() },
            ]);
            csv.push_str(&format!(
                "{},{},{:.1},{:.1},{:.4},{:.4}\n",
                d,
                r.arm,
                r.latency,
                r.energy,
                r.latency / max_lat,
                r.energy / max_en
            ));
        }
    }
    write_csv(&cfg.out_dir, "fig2.csv", &csv)?;
    Ok(format!(
        "Fig. 2 — mapping x sparse-strategy interplay (mobile, 256^3 GEMM)\n{}",
        table.render()
    ))
}

/// Winners per density — used by tests and EXPERIMENTS.md.
pub fn winners(cfg: &ExpConfig) -> Vec<(f64, &'static str)> {
    let _ = cfg;
    let densities = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9];
    let mut out = Vec::new();
    for &d in &densities {
        let w = Workload::spmm("fig2", 256, 256, 256, d, d);
        let ev = NativeEvaluator::new(w.clone(), Platform::mobile());
        let spec = GenomeSpec::for_workload(&w);
        let best = Arm::ALL
            .iter()
            .map(|&arm| {
                let cb = ev.breakdown(&build_design(&spec, &w, arm));
                (arm, cb.edp, cb.valid)
            })
            .filter(|(_, _, v)| *v > 0.5)
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        if let Some((arm, _, _)) = best {
            out.push((d, arm.name()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_arms_decode_validly_at_moderate_density() {
        let w = Workload::spmm("t", 256, 256, 256, 0.3, 0.3);
        let spec = GenomeSpec::for_workload(&w);
        let ev = NativeEvaluator::new(w.clone(), Platform::mobile());
        for arm in Arm::ALL {
            let d = build_design(&spec, &w, arm);
            let cb = ev.breakdown(&d);
            assert!(cb.valid > 0.5, "{} invalid", arm.name());
        }
    }

    #[test]
    fn stationarity_actually_differs() {
        // OS and IS arms must differ in DRAM traffic profile.
        let w = Workload::spmm("t", 256, 256, 256, 0.3, 0.3);
        let spec = GenomeSpec::for_workload(&w);
        let ev = NativeEvaluator::new(w.clone(), Platform::mobile());
        let os = ev.breakdown(&build_design(&spec, &w, Arm::OsCsr));
        let is = ev.breakdown(&build_design(&spec, &w, Arm::IsCsr));
        assert_ne!(os.energy_dram_pj, is.energy_dram_pj);
    }

    #[test]
    fn no_single_arm_wins_everywhere() {
        // The paper's core motivation claim (Fig. 2).
        let cfg = ExpConfig::default();
        let w = winners(&cfg);
        assert!(w.len() >= 4);
        let distinct: std::collections::HashSet<&str> =
            w.iter().map(|&(_, a)| a).collect();
        assert!(
            distinct.len() >= 2,
            "a single arm won at every density: {w:?}"
        );
    }

    #[test]
    fn run_produces_report_and_csv() {
        let cfg = ExpConfig {
            out_dir: std::env::temp_dir().join("sparsemap_fig2"),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert!(report.contains("OS+CSR"));
        assert!(cfg.out_dir.join("fig2.csv").exists());
    }
}
