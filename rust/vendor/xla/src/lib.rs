//! Stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The offline build environment cannot ship the real XLA toolchain, so
//! this placeholder lets `--features xla` builds *link*: every runtime
//! entry point returns an [`XlaError`] explaining that the stub is active.
//! The default (native-only) build never compiles this crate at all.
//!
//! To execute the AOT artifacts for real, replace this directory with the
//! actual xla-rs crate (same API surface) and rebuild with
//! `cargo build --release --features xla`.

use std::fmt;

/// Error type mirroring xla-rs's; printed with `{:?}` at call sites.
#[derive(Clone, Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: built against the in-tree xla stub; replace rust/vendor/xla with the real \
         xla-rs crate to enable PJRT execution"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        stub("Literal::to_tuple1")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        stub("Literal::to_tuple2")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }
}
