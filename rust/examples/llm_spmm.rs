//! LLM SpMM scenario: the sparseGPT-style workloads of Table III
//! (mm8–mm10: dense activations x 50%-pruned weights) searched across all
//! three platforms — the "adapting to new sparse workloads" story of the
//! paper's introduction, driven through the batch API (`api::run_batch`
//! fans the 18 arms out across worker threads).
//!
//! ```bash
//! cargo run --release --example llm_spmm -- [budget]
//! ```

use sparsemap::api::{run_batch, SearchRequest};
use sparsemap::util::table::{sci, Table};
use sparsemap::workload::table3;

fn main() -> anyhow::Result<()> {
    let budget: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4_000);
    let workloads = ["mm8", "mm9", "mm10"];
    let platforms = ["edge", "mobile", "cloud"];
    let methods = ["sparsemap", "sage-like"];

    for wl in &workloads {
        let w = table3::by_id(wl).unwrap();
        println!(
            "{wl}: {}x{} (dense) x {}x{} @ {:.0}% weight density",
            w.dims[0].size,
            w.dims[1].size,
            w.dims[1].size,
            w.dims[2].size,
            100.0 * w.tensors[1].density.avg()
        );
    }

    // One request per (workload, platform, method) arm; the pool runs
    // them 6 at a time.
    let mut requests = Vec::new();
    for wl in &workloads {
        for plat in &platforms {
            for m in &methods {
                requests.push(
                    SearchRequest::new()
                        .workload_named(wl)
                        .platform_named(plat)
                        .method(m)
                        .budget(budget)
                        .seed(7),
                );
            }
        }
    }
    let reports = run_batch(requests, 6)?;
    let find = |wl: &str, plat: &str, m: &str| {
        reports
            .iter()
            .map(|r| &r.outcome)
            .find(|o| o.workload == wl && o.platform == plat && o.method == m)
            .expect("arm ran")
    };

    let mut table = Table::new(&["workload", "platform", "sparsemap EDP", "sage-like EDP", "gain"]);
    for wl in &workloads {
        for plat in &platforms {
            let ours = find(wl, plat, "sparsemap");
            let sage = find(wl, plat, "sage-like");
            let gain = sage.best_edp / ours.best_edp;
            table.row(vec![
                wl.to_string(),
                plat.to_string(),
                sci(ours.best_edp),
                if sage.found_valid() { sci(sage.best_edp) } else { "-".into() },
                if gain.is_finite() { format!("{gain:.2}x") } else { "inf".into() },
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "joint mapping+strategy search vs fixed-mapping format search, budget {budget}/arm"
    );
    Ok(())
}
