"""AOT pipeline: lower the L2 evaluator + demo kernel to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts relative to python/):
  cost_model.hlo.txt  — evaluate_batch, f32[256,48] × f32[16] → (f32[256,4],)
  spmm_demo.hlo.txt   — spmm_demo, 4× f32[64,64] → (f32[64,64], f32[1])
  meta.json           — schema version, shapes; asserted by the Rust runtime.

Run via `make artifacts` (no-op when inputs are unchanged).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cost_model() -> str:
    lowered = jax.jit(model.evaluate_batch).lower(*model.example_args())
    return to_hlo_text(lowered)


def lower_spmm_demo() -> str:
    lowered = jax.jit(model.spmm_demo).lower(*model.demo_args())
    return to_hlo_text(lowered)


def metadata() -> dict:
    from .kernels import ref

    return {
        "schema_version": model.SCHEMA_VERSION,
        "batch": model.AOT_BATCH,
        "num_features": ref.NUM_FEATURES,
        "num_platform_features": ref.NUM_PLATFORM_FEATURES,
        "outputs": ["energy_pj", "cycles", "edp", "valid"],
        "demo_shape": [model.DEMO_M, model.DEMO_K, model.DEMO_N],
        "artifacts": {
            "cost_model": "cost_model.hlo.txt",
            "spmm_demo": "spmm_demo.hlo.txt",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cost_hlo = lower_cost_model()
    with open(os.path.join(args.out_dir, "cost_model.hlo.txt"), "w") as f:
        f.write(cost_hlo)
    print(f"cost_model.hlo.txt: {len(cost_hlo)} chars")

    demo_hlo = lower_spmm_demo()
    with open(os.path.join(args.out_dir, "spmm_demo.hlo.txt"), "w") as f:
        f.write(demo_hlo)
    print(f"spmm_demo.hlo.txt: {len(demo_hlo)} chars")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(metadata(), f, indent=2, sort_keys=True)
    print("meta.json written")


if __name__ == "__main__":
    main()
