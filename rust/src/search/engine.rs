//! The staged, interned evaluation engine — the search hot path.
//!
//! SparseMap's entire sample budget is spent inside one function
//! (genome → decode → feature extraction → cost), so this module makes
//! per-candidate evaluation as close to free as the population's
//! structure allows. Three layers:
//!
//! 1. **Genome interning** ([`Interner`]) — genomes are hash-consed to
//!    dense `u32` ids with the in-tree Fx hasher
//!    ([`crate::util::hash`]). Keys are stored word-packed
//!    ([`PackedWords`]: two `u32` genes per `u64` word), so hashing and
//!    equality run half the Fx rounds of the element-wise `[u32]`
//!    layout, and lookups probe by a reusable `&[u64]` scratch buffer —
//!    *nothing is cloned or allocated on a hit* (the old pipeline keyed
//!    a `HashMap` on cloned `Vec<u32>` genomes). The result caches are
//!    plain `Vec<Option<EvalResult>>` tables indexed by id.
//!
//! 2. **Stage-level memoization** ([`StageEngine`]) — the genome's
//!    natural segments (mapping genes | per-tensor format genes | S/G
//!    genes, per [`crate::genome::GenomeSpec`]) are evaluated as the segment-pure
//!    stages of `model::features`: the decoded mapping and its derived
//!    features are cached per distinct *mapping segment*, and per-tensor
//!    compression stats per `(mapping, format-gene)` pair. An offspring
//!    that mutated only its S/G genes reuses the parent's decoded loop
//!    nest and tile features wholesale and pays only the allocation-free
//!    [`crate::model::assemble`] + cost arithmetic. The assembly phase
//!    itself runs batched by default: staged genomes are grouped by
//!    mapping id into structure-of-arrays tables over the `Copy` stage
//!    outputs and the cost model runs over contiguous `(lo, hi)` index
//!    ranges (`set_batched(false)` keeps the per-genome walk as the
//!    parity reference).
//!
//! 3. **Scratch reuse** — all per-batch work lists live in reusable
//!    buffers owned by the engine/context, so steady-state evaluation of
//!    a population performs no per-genome heap allocation (asserted by
//!    `rust/tests/alloc_steady_state.rs` with a counting allocator).
//!
//! Staging never changes a result: the from-scratch path
//! ([`crate::model::NativeEvaluator::eval_genome`]) composes the *same*
//! stage functions, and `rust/tests/engine_parity.rs` pins bit-for-bit
//! trajectory parity across methods and thread counts.
//!
//! **Memory bounds.** All three layers are capped with budget-derived
//! bounds mirroring the eval-cache bound (entries only ever appear for
//! budget-debited submissions, so the caps are invariants rather than
//! working-set limits): interner ≤ budget distinct keys, mapping stages
//! ≤ budget segments, format stages ≤ `3 × budget` pairs. If a cap is
//! ever reached the engine degrades gracefully — new genomes are
//! evaluated from scratch and simply not cached.

use crate::genome::{assign_formats, decode_mapping, FORMAT_GENES_PER_TENSOR};
use crate::model::{
    assemble, format_stage, mapping_stage, EvalResult, MapFeats, MappingStage, NativeEvaluator,
    TensorCompression, WorkloadConsts,
};
use crate::obs::metrics::{STAGE_ASSEMBLE, STAGE_DECODE, STAGE_FORMAT, STAGE_MAPPING};
use crate::obs::Metrics;
use crate::sparse::SgMechanism;
use crate::util::hash::{pack_genes_into, FxHashMap, PackedWords};
use crate::util::threadpool::ThreadPool;
use crate::workload::NUM_TENSORS;
use std::sync::Arc;
use std::time::Instant;
use super::{fan_out_indexed, fan_out_shared};

/// Advance a phase clock (present only when metrics are attached) and
/// return the finished phase's elapsed nanoseconds. With no clock this
/// is a dead branch — the uninstrumented hot path does no timing work.
fn lap_ns(clock: &mut Option<Instant>) -> u64 {
    match clock {
        Some(t) => {
            let ns = t.elapsed().as_nanos() as u64;
            *t = Instant::now();
            ns
        }
        None => 0,
    }
}

/// Hash-consed genome store: each distinct gene vector gets a dense
/// `u32` id. Keys live word-packed ([`PackedWords`]) so hashing and
/// equality run over `u64` words; lookups pack into a reusable scratch
/// buffer and probe by `&[u64]` — no clone, no allocation on a hit.
/// Inserts allocate exactly twice (the packed key and the raw-gene
/// `Arc<[u32]>` the parallel pipeline shares by refcount).
pub struct Interner {
    ids: FxHashMap<PackedWords, u32>,
    genomes: Vec<Arc<[u32]>>,
    /// Reusable word-packing buffer for allocation-free probes.
    pack_scratch: Vec<u64>,
    cap: usize,
}

impl Interner {
    /// `cap` bounds the number of distinct keys (budget-derived; see
    /// module docs).
    pub fn new(cap: usize) -> Interner {
        Interner {
            ids: FxHashMap::default(),
            genomes: Vec::new(),
            pack_scratch: Vec::new(),
            cap,
        }
    }

    /// Distinct genomes interned so far.
    pub fn len(&self) -> usize {
        self.genomes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.genomes.is_empty()
    }

    /// Intern a genome: returns its dense id, or `None` when the key is
    /// new but the interner is at capacity (caller falls back to an
    /// uncached evaluation).
    pub fn intern(&mut self, g: &[u32]) -> Option<u32> {
        pack_genes_into(g, &mut self.pack_scratch);
        if let Some(&id) = self.ids.get(self.pack_scratch.as_slice()) {
            return Some(id);
        }
        if self.genomes.len() >= self.cap {
            return None;
        }
        let key = PackedWords(Arc::from(self.pack_scratch.as_slice()));
        let id = self.genomes.len() as u32;
        self.ids.insert(key, id);
        self.genomes.push(Arc::from(g));
        Some(id)
    }

    /// Look up without inserting (cold path: packs into a local buffer).
    pub fn get(&self, g: &[u32]) -> Option<u32> {
        let mut buf = Vec::with_capacity(g.len().div_ceil(2));
        pack_genes_into(g, &mut buf);
        self.ids.get(buf.as_slice()).copied()
    }

    /// The genome behind an id.
    pub fn genome(&self, id: u32) -> &Arc<[u32]> {
        &self.genomes[id as usize]
    }
}

/// Format-stage cache key: which mapping, which tensor, which format
/// genes. Exact (no hash truncation) and `Copy` — lookups never allocate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct FmtKey {
    map: u32,
    tensor: u8,
    genes: [u32; FORMAT_GENES_PER_TENSOR],
}

/// Where a miss's mapping stage comes from.
#[derive(Clone, Copy)]
enum MapRef {
    /// Already cached under this id.
    Cached(u32),
    /// Will be computed this batch (index into the pending list).
    Pending(u32),
    /// Cache at capacity: evaluate this genome from scratch.
    Scratch,
}

/// Where a miss's per-tensor format stage comes from.
#[derive(Clone, Copy)]
enum FmtRef {
    Ready(TensorCompression),
    /// Index into this batch's pending-format list.
    Pending(u32),
}

/// Per-genome assembly plan.
#[derive(Clone, Copy)]
enum AsmSlot {
    Staged { map: u32, fmt: [FmtRef; NUM_TENSORS] },
    Scratch,
}

/// `Copy` payload for the per-genome assembly walk (the batched path's
/// parity reference): the mapping features, the three tensors'
/// compression stats and the S/G mechanisms — everything [`assemble`]
/// needs, nothing on the heap.
#[derive(Clone, Copy)]
struct AsmItem {
    mf: MapFeats,
    comp: [TensorCompression; NUM_TENSORS],
    sg: [SgMechanism; 3],
}

/// Structure-of-arrays tables for the batched assembly phase: one row
/// per staged genome, grouped by mapping id so strategy-only siblings
/// index one shared [`MapFeats`] entry instead of carrying a copy each.
/// Everything is `Copy` data in flat vectors — the cost model walks
/// contiguous memory, and the buffers are reused across batches.
#[derive(Default)]
struct SoaTables {
    /// One entry per distinct mapping id in the batch (group order).
    feats: Vec<MapFeats>,
    /// Per staged genome: index into `feats`.
    group: Vec<u32>,
    comp: Vec<[TensorCompression; NUM_TENSORS]>,
    sg: Vec<[SgMechanism; 3]>,
    /// Per staged genome: original submission index (write-back target,
    /// which is what keeps batched results in submission order).
    src: Vec<u32>,
}

/// Stage-memoizing evaluator for one `(workload, platform)` pair.
///
/// Owned by [`crate::search::EvalContext`] for native backends; also
/// usable standalone (benchmarks, the allocation test). Results are
/// **not** memoized per genome here — that is the context's result
/// cache; the engine memoizes the *stages* beneath a result.
pub struct StageEngine {
    eval: Arc<NativeEvaluator>,
    consts: WorkloadConsts,
    map_ids: FxHashMap<Arc<[u32]>, u32>,
    map_stages: Vec<Arc<MappingStage>>,
    fmt_cache: FxHashMap<FmtKey, TensorCompression>,
    map_cap: usize,
    fmt_cap: usize,
    stage_hits: usize,
    stage_misses: usize,
    /// Metrics scope (see [`crate::obs`]): per-phase batch timings land
    /// in `stage_ns` (decode = phase-1 resolution, mapping = phase-2
    /// stage compute, format = phases 3/3b, assemble = phase 4 + the
    /// cap-degraded scratch path) and hit/miss deltas in
    /// `stage_hits`/`stage_misses`; the batched pipeline additionally
    /// samples `brood_size` (submissions per engine batch) and
    /// `soa_slice_ns` (the SoA cost-model sweep) once per batch. `None`
    /// (the default) records nothing and costs one branch per batch.
    metrics: Option<Arc<Metrics>>,
    /// Batched SoA assembly (default). Off = the per-genome assembly
    /// walk, kept as the parity suite's reference path.
    batched: bool,
    // --- reusable per-batch scratch (layer 3) ---------------------------
    map_refs: Vec<MapRef>,
    pending_segs: Vec<Arc<[u32]>>,
    /// Packed keys of `pending_segs`, in the same order (inserted into
    /// `map_ids` once phase 2 computes the stages).
    pending_packed: Vec<PackedWords>,
    pending_map: FxHashMap<PackedWords, u32>,
    /// Reusable word-packing buffer for mapping-segment probes.
    seg_scratch: Vec<u64>,
    asm: Vec<AsmSlot>,
    pending_fmt: Vec<FmtKey>,
    pending_fmt_map: FxHashMap<FmtKey, u32>,
    fmt_computed: Vec<TensorCompression>,
    /// Phase-3b work list (key + its mapping stage), reused per batch.
    fmt_jobs: Vec<(FmtKey, Arc<MappingStage>)>,
    asm_idx: Vec<u32>,
    asm_items: Vec<AsmItem>,
    soa: SoaTables,
    /// `(mapping id, submission index)` pairs, sorted to group siblings.
    soa_order: Vec<(u32, u32)>,
    scratch_idx: Vec<u32>,
    scratch_genomes: Vec<Arc<[u32]>>,
}

impl StageEngine {
    /// `budget` derives the cache caps (see module docs).
    pub fn new(eval: Arc<NativeEvaluator>, budget: usize) -> StageEngine {
        let consts = WorkloadConsts::of(&eval.workload);
        StageEngine {
            eval,
            consts,
            map_ids: FxHashMap::default(),
            map_stages: Vec::new(),
            fmt_cache: FxHashMap::default(),
            map_cap: budget.max(1),
            fmt_cap: budget.max(1) * NUM_TENSORS,
            stage_hits: 0,
            stage_misses: 0,
            metrics: None,
            batched: true,
            map_refs: Vec::new(),
            pending_segs: Vec::new(),
            pending_packed: Vec::new(),
            pending_map: FxHashMap::default(),
            seg_scratch: Vec::new(),
            asm: Vec::new(),
            pending_fmt: Vec::new(),
            pending_fmt_map: FxHashMap::default(),
            fmt_computed: Vec::new(),
            fmt_jobs: Vec::new(),
            asm_idx: Vec::new(),
            asm_items: Vec::new(),
            soa: SoaTables::default(),
            soa_order: Vec::new(),
            scratch_idx: Vec::new(),
            scratch_genomes: Vec::new(),
        }
    }

    /// Toggle the batched SoA assembly phase (on by default). Off forces
    /// the per-genome assembly walk — the reference the batched-parity
    /// tests compare against. Results are bit-identical either way.
    pub fn set_batched(&mut self, batched: bool) {
        self.batched = batched;
    }

    /// Builder form of [`StageEngine::set_batched`].
    pub fn with_batched(mut self, batched: bool) -> StageEngine {
        self.set_batched(batched);
        self
    }

    /// Override the budget-derived cache caps (tests of the degraded
    /// path; production code keeps the defaults).
    pub fn with_caps(mut self, map_cap: usize, fmt_cap: usize) -> StageEngine {
        self.map_cap = map_cap;
        self.fmt_cap = fmt_cap;
        self
    }

    /// Stage-level cache hits: one per memoized (or batch-shared) stage
    /// reused — a single evaluation can contribute up to 4 (mapping +
    /// three format stages).
    pub fn stage_hits(&self) -> usize {
        self.stage_hits
    }

    /// Stages computed from scratch.
    pub fn stage_misses(&self) -> usize {
        self.stage_misses
    }

    /// Rebase the hit/miss counters to checkpointed values. Restoring an
    /// `EvalContext` re-warms the stage caches by replaying the cached
    /// genomes through [`StageEngine::eval_batch`], which perturbs the
    /// counters; this resets them to the suspended run's telemetry so
    /// post-resume counts match an uninterrupted run.
    pub(crate) fn set_counters(&mut self, hits: usize, misses: usize) {
        self.stage_hits = hits;
        self.stage_misses = misses;
    }

    /// Cached (mapping, format) stage counts — observability + cap tests.
    pub fn cache_sizes(&self) -> (usize, usize) {
        (self.map_stages.len(), self.fmt_cache.len())
    }

    /// Attach (or detach) a metrics scope — see the field docs. Owned by
    /// [`EvalContext::set_metrics`](crate::search::EvalContext) for
    /// engine instances embedded in a context.
    pub fn set_metrics(&mut self, metrics: Option<Arc<Metrics>>) {
        self.metrics = metrics;
    }

    fn compute_mapping_stage(ev: &NativeEvaluator, seg: &[u32]) -> MappingStage {
        let m = decode_mapping(&ev.spec, &ev.workload, seg);
        mapping_stage(&m, &ev.workload, &ev.platform)
    }

    fn compute_format_stage(
        ev: &NativeEvaluator,
        stage: &MappingStage,
        tensor: usize,
        genes: &[u32],
    ) -> TensorCompression {
        let formats = assign_formats(&stage.ranks[tensor], genes);
        format_stage(&ev.workload, tensor, &stage.ranks[tensor], &formats)
    }

    /// Evaluate a batch of genomes through the staged pipeline, fanning
    /// stage computation and assembly out over `pool` when present.
    /// Results are in submission order and bit-identical to
    /// `NativeEvaluator::eval_genome` per genome (the parity suite's
    /// contract). The caller is responsible for budget accounting and
    /// result caching.
    pub fn eval_batch(
        &mut self,
        genomes: &[Arc<[u32]>],
        pool: Option<&Arc<ThreadPool>>,
    ) -> Vec<EvalResult> {
        let n = genomes.len();
        if n == 0 {
            return Vec::new();
        }
        let spec = &self.eval.spec;
        let (fs, sg_start) = (spec.format_start, spec.sg_start);
        let obs = self.metrics.clone();
        let mut clock = obs.as_ref().map(|_| Instant::now());
        let (hits0, misses0) = (self.stage_hits, self.stage_misses);
        if let Some(m) = &obs {
            m.brood_size.record(n as u64);
        }

        // --- phase 1: resolve mapping segments --------------------------
        self.map_refs.clear();
        self.pending_segs.clear();
        self.pending_packed.clear();
        self.pending_map.clear();
        for g in genomes {
            let seg = &g[..fs];
            pack_genes_into(seg, &mut self.seg_scratch);
            if let Some(&id) = self.map_ids.get(self.seg_scratch.as_slice()) {
                self.map_refs.push(MapRef::Cached(id));
                self.stage_hits += 1;
            } else if let Some(&pi) = self.pending_map.get(self.seg_scratch.as_slice()) {
                // Another miss in this batch already introduces it:
                // batch-local sharing is a hit too.
                self.map_refs.push(MapRef::Pending(pi));
                self.stage_hits += 1;
            } else if self.map_stages.len() + self.pending_segs.len() >= self.map_cap {
                self.map_refs.push(MapRef::Scratch);
            } else {
                let pi = self.pending_segs.len() as u32;
                let packed = PackedWords(Arc::from(self.seg_scratch.as_slice()));
                self.pending_map.insert(packed.clone(), pi);
                self.pending_packed.push(packed);
                self.pending_segs.push(Arc::from(seg));
                self.map_refs.push(MapRef::Pending(pi));
                self.stage_misses += 1;
            }
        }

        if let Some(m) = &obs {
            m.stage_ns[STAGE_DECODE].record(lap_ns(&mut clock));
        }

        // --- phase 2: compute missing mapping stages --------------------
        let map_base = self.map_stages.len() as u32;
        if !self.pending_segs.is_empty() {
            let ev = Arc::clone(&self.eval);
            let (segs, computed) =
                fan_out_shared(pool, std::mem::take(&mut self.pending_segs), move |seg| {
                    Self::compute_mapping_stage(&ev, seg)
                });
            self.pending_segs = segs;
            self.pending_segs.clear();
            for (packed, st) in self.pending_packed.drain(..).zip(computed) {
                let id = self.map_stages.len() as u32;
                self.map_stages.push(Arc::new(st));
                self.map_ids.insert(packed, id);
            }
        }

        if let Some(m) = &obs {
            m.stage_ns[STAGE_MAPPING].record(lap_ns(&mut clock));
        }

        // --- phase 3: resolve per-tensor format stages ------------------
        self.asm.clear();
        self.pending_fmt.clear();
        self.pending_fmt_map.clear();
        for (g, mr) in genomes.iter().zip(&self.map_refs) {
            let map = match *mr {
                MapRef::Cached(id) => id,
                MapRef::Pending(pi) => map_base + pi,
                MapRef::Scratch => {
                    self.asm.push(AsmSlot::Scratch);
                    continue;
                }
            };
            let mut fmt = [FmtRef::Pending(0); NUM_TENSORS];
            for (t, slot) in fmt.iter_mut().enumerate() {
                let genes: [u32; FORMAT_GENES_PER_TENSOR] = g
                    [fs + t * FORMAT_GENES_PER_TENSOR..fs + (t + 1) * FORMAT_GENES_PER_TENSOR]
                    .try_into()
                    .unwrap();
                let key = FmtKey { map, tensor: t as u8, genes };
                if let Some(&tc) = self.fmt_cache.get(&key) {
                    *slot = FmtRef::Ready(tc);
                    self.stage_hits += 1;
                } else if let Some(&pi) = self.pending_fmt_map.get(&key) {
                    *slot = FmtRef::Pending(pi);
                    self.stage_hits += 1;
                } else if self.fmt_cache.len() + self.pending_fmt.len() >= self.fmt_cap {
                    // Cap reached: compute uncached, inline.
                    let stage = &self.map_stages[map as usize];
                    *slot =
                        FmtRef::Ready(Self::compute_format_stage(&self.eval, stage, t, &genes));
                } else {
                    let pi = self.pending_fmt.len() as u32;
                    self.pending_fmt_map.insert(key, pi);
                    self.pending_fmt.push(key);
                    *slot = FmtRef::Pending(pi);
                    self.stage_misses += 1;
                }
            }
            self.asm.push(AsmSlot::Staged { map, fmt });
        }

        // --- phase 3b: compute missing format stages --------------------
        self.fmt_computed.clear();
        if !self.pending_fmt.is_empty() {
            self.fmt_jobs.clear();
            self.fmt_jobs.extend(
                self.pending_fmt
                    .iter()
                    .map(|&k| (k, Arc::clone(&self.map_stages[k.map as usize]))),
            );
            let ev = Arc::clone(&self.eval);
            let (jobs, computed) =
                fan_out_shared(pool, std::mem::take(&mut self.fmt_jobs), move |(k, stage)| {
                    Self::compute_format_stage(&ev, stage, k.tensor as usize, &k.genes)
                });
            self.fmt_jobs = jobs;
            // Drop the stage Arc refs promptly; keep the capacity.
            self.fmt_jobs.clear();
            self.fmt_computed.extend(computed);
            for (k, tc) in self.pending_fmt.iter().zip(&self.fmt_computed) {
                self.fmt_cache.insert(*k, *tc);
            }
        }

        if let Some(m) = &obs {
            m.stage_ns[STAGE_FORMAT].record(lap_ns(&mut clock));
        }

        // --- phase 4: assembly + cost ------------------------------------
        let mut out = vec![EvalResult::dead(); n];
        self.scratch_idx.clear();
        self.scratch_genomes.clear();
        if self.batched {
            // Batched SoA path: group staged genomes by mapping id so
            // strategy-only siblings index one shared MapFeats row, then
            // run the cost model over the contiguous tables as (lo, hi)
            // index ranges. Results write back through `src`, so output
            // stays in submission order and every downstream trajectory
            // is bit-identical to the per-genome walk.
            self.soa_order.clear();
            for (i, slot) in self.asm.iter().enumerate() {
                match *slot {
                    AsmSlot::Scratch => {
                        self.scratch_idx.push(i as u32);
                        self.scratch_genomes.push(Arc::clone(&genomes[i]));
                    }
                    AsmSlot::Staged { map, .. } => self.soa_order.push((map, i as u32)),
                }
            }
            // sort_unstable is deterministic here — (map, index) pairs
            // are distinct — and allocation-free.
            self.soa_order.sort_unstable();
            {
                let t = &mut self.soa;
                t.feats.clear();
                t.group.clear();
                t.comp.clear();
                t.sg.clear();
                t.src.clear();
                let mut last_map = None;
                for &(map, i) in &self.soa_order {
                    if last_map != Some(map) {
                        t.feats.push(self.map_stages[map as usize].feats);
                        last_map = Some(map);
                    }
                    let AsmSlot::Staged { fmt, .. } = self.asm[i as usize] else {
                        unreachable!("soa_order only holds staged slots")
                    };
                    let resolve = |r: FmtRef| match r {
                        FmtRef::Ready(tc) => tc,
                        FmtRef::Pending(pi) => self.fmt_computed[pi as usize],
                    };
                    let g = &genomes[i as usize];
                    t.group.push(t.feats.len() as u32 - 1);
                    t.comp.push([resolve(fmt[0]), resolve(fmt[1]), resolve(fmt[2])]);
                    t.sg.push([
                        SgMechanism::from_gene(g[sg_start]),
                        SgMechanism::from_gene(g[sg_start + 1]),
                        SgMechanism::from_gene(g[sg_start + 2]),
                    ]);
                    t.src.push(i);
                }
            }
            let staged_n = self.soa.src.len();
            if staged_n > 0 {
                let ev = Arc::clone(&self.eval);
                let consts = self.consts;
                let slice_clock = obs.as_ref().map(|_| Instant::now());
                let (tables, results) =
                    fan_out_indexed(pool, std::mem::take(&mut self.soa), staged_n, move |t, j| {
                        ev.eval_features(&assemble(
                            &consts,
                            &t.feats[t.group[j] as usize],
                            &t.comp[j],
                            t.sg[j],
                        ))
                    });
                for (&i, r) in tables.src.iter().zip(&results) {
                    out[i as usize] = *r;
                }
                self.soa = tables;
                if let (Some(m), Some(t0)) = (&obs, slice_clock) {
                    m.soa_slice_ns.record(t0.elapsed().as_nanos() as u64);
                }
            }
        } else {
            // Per-genome reference walk (parity suite; `set_batched(false)`).
            self.asm_idx.clear();
            self.asm_items.clear();
            for (i, (g, slot)) in genomes.iter().zip(&self.asm).enumerate() {
                match *slot {
                    AsmSlot::Scratch => {
                        self.scratch_idx.push(i as u32);
                        self.scratch_genomes.push(Arc::clone(g));
                    }
                    AsmSlot::Staged { map, fmt } => {
                        let resolve = |r: FmtRef| match r {
                            FmtRef::Ready(tc) => tc,
                            FmtRef::Pending(pi) => self.fmt_computed[pi as usize],
                        };
                        let item = AsmItem {
                            mf: self.map_stages[map as usize].feats,
                            comp: [resolve(fmt[0]), resolve(fmt[1]), resolve(fmt[2])],
                            sg: [
                                SgMechanism::from_gene(g[sg_start]),
                                SgMechanism::from_gene(g[sg_start + 1]),
                                SgMechanism::from_gene(g[sg_start + 2]),
                            ],
                        };
                        self.asm_idx.push(i as u32);
                        self.asm_items.push(item);
                    }
                }
            }
            if !self.asm_items.is_empty() {
                let ev = Arc::clone(&self.eval);
                let consts = self.consts;
                let (items, results) =
                    fan_out_shared(pool, std::mem::take(&mut self.asm_items), move |it| {
                        ev.eval_features(&assemble(&consts, &it.mf, &it.comp, it.sg))
                    });
                self.asm_items = items;
                for (&i, r) in self.asm_idx.iter().zip(&results) {
                    out[i as usize] = *r;
                }
            }
        }
        // Cap-degraded genomes evaluate from scratch — still fanned out
        // over the pool so the degraded mode keeps its parallelism.
        if !self.scratch_genomes.is_empty() {
            let ev = Arc::clone(&self.eval);
            let (bufs, results) =
                fan_out_shared(pool, std::mem::take(&mut self.scratch_genomes), move |g| {
                    ev.eval_genome(g)
                });
            self.scratch_genomes = bufs;
            for (&i, r) in self.scratch_idx.iter().zip(&results) {
                out[i as usize] = *r;
            }
            // Drop the Arc refs promptly (these are the rare over-cap
            // genomes; no point pinning them between batches).
            self.scratch_genomes.clear();
        }
        if let Some(m) = &obs {
            m.stage_ns[STAGE_ASSEMBLE].record(lap_ns(&mut clock));
            m.stage_hits.add((self.stage_hits - hits0) as u64);
            m.stage_misses.add((self.stage_misses - misses0) as u64);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::util::rng::Pcg64;
    use crate::workload::Workload;

    fn engine(budget: usize) -> StageEngine {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        StageEngine::new(Arc::new(NativeEvaluator::new(w, Platform::edge())), budget)
    }

    fn arcs(genomes: &[Vec<u32>]) -> Vec<Arc<[u32]>> {
        genomes.iter().map(|g| Arc::from(g.as_slice())).collect()
    }

    #[test]
    fn interner_dedups_and_caps() {
        let mut it = Interner::new(2);
        let a = it.intern(&[1, 2, 3]).unwrap();
        assert_eq!(it.intern(&[1, 2, 3]), Some(a), "same key, same id");
        let b = it.intern(&[4, 5, 6]).unwrap();
        assert_ne!(a, b);
        assert_eq!(it.len(), 2);
        // At cap: known keys still resolve, new keys are refused.
        assert_eq!(it.intern(&[1, 2, 3]), Some(a));
        assert_eq!(it.intern(&[7, 8, 9]), None);
        assert_eq!(it.len(), 2);
        assert_eq!(&it.genome(b)[..], &[4, 5, 6]);
        assert_eq!(it.get(&[4, 5, 6]), Some(b));
        assert_eq!(it.get(&[9, 9, 9]), None);
    }

    #[test]
    fn staged_matches_from_scratch_bitwise() {
        let mut e = engine(10_000);
        let mut rng = Pcg64::seeded(3);
        let genomes: Vec<Vec<u32>> = (0..200).map(|_| e.eval.spec.random(&mut rng)).collect();
        let staged = e.eval_batch(&arcs(&genomes), None);
        for (g, r) in genomes.iter().zip(&staged) {
            let scratch = e.eval.eval_genome(g);
            assert_eq!(*r, scratch, "staged diverged on {g:?}");
        }
        // Re-evaluating the same batch is all stage hits, same results.
        let before = e.stage_misses();
        let again = e.eval_batch(&arcs(&genomes), None);
        assert_eq!(again, staged);
        assert_eq!(e.stage_misses(), before, "warm batch must not recompute stages");
    }

    #[test]
    fn offspring_reuse_counts_stage_hits() {
        let mut e = engine(10_000);
        let mut rng = Pcg64::seeded(5);
        let base = e.eval.spec.random(&mut rng);
        // 10 offspring mutating only the S/G genes: one mapping stage,
        // three format stages, everything else shared.
        let sg = e.eval.spec.sg_start;
        let pop: Vec<Vec<u32>> = (0..10u32)
            .map(|i| {
                let mut g = base.clone();
                g[sg] = i % 7;
                g
            })
            .collect();
        let r = e.eval_batch(&arcs(&pop), None);
        for (g, r) in pop.iter().zip(&r) {
            assert_eq!(*r, e.eval.eval_genome(g));
        }
        // 1 mapping + 3 format misses; the other 9 genomes hit all four.
        assert_eq!(e.stage_misses(), 4);
        assert_eq!(e.stage_hits(), 9 * 4);
        assert_eq!(e.cache_sizes(), (1, 3));
    }

    #[test]
    fn parallel_staged_is_bit_identical() {
        let mut serial = engine(10_000);
        let mut par = engine(10_000);
        let pool = Arc::new(ThreadPool::new(4));
        let mut rng = Pcg64::seeded(7);
        let genomes: Vec<Vec<u32>> =
            (0..300).map(|_| serial.eval.spec.random(&mut rng)).collect();
        let a = serial.eval_batch(&arcs(&genomes), None);
        let b = par.eval_batch(&arcs(&genomes), Some(&pool));
        assert_eq!(a, b);
        assert_eq!(serial.stage_misses(), par.stage_misses());
    }

    #[test]
    fn metrics_scope_records_stage_timings_and_counters() {
        let mut e = engine(10_000);
        let m = Arc::new(crate::obs::Metrics::new());
        e.set_metrics(Some(Arc::clone(&m)));
        let mut rng = Pcg64::seeded(9);
        let genomes: Vec<Vec<u32>> = (0..20).map(|_| e.eval.spec.random(&mut rng)).collect();
        e.eval_batch(&arcs(&genomes), None);
        for (h, name) in m.stage_ns.iter().zip(crate::obs::STAGE_NAMES) {
            assert_eq!(h.snapshot().count, 1, "one {name} sample per batch");
        }
        // The batched pipeline's own histograms: one brood-size sample
        // (the submission count) and one SoA slice timing per batch.
        let brood = m.brood_size.snapshot();
        assert_eq!(brood.count, 1);
        assert_eq!(brood.sum, genomes.len() as u64);
        assert_eq!(m.soa_slice_ns.snapshot().count, 1);
        assert_eq!(m.stage_hits.get() as usize, e.stage_hits());
        assert_eq!(m.stage_misses.get() as usize, e.stage_misses());
        // Detaching freezes the scope; results are unaffected either way.
        e.set_metrics(None);
        let r = e.eval_batch(&arcs(&genomes), None);
        assert_eq!(m.stage_ns[0].snapshot().count, 1);
        assert_eq!(m.brood_size.snapshot().count, 1);
        for (g, r) in genomes.iter().zip(&r) {
            assert_eq!(*r, e.eval.eval_genome(g));
        }
    }

    #[test]
    fn batched_and_per_genome_assembly_agree_bitwise() {
        let mut batched = engine(10_000);
        let mut pergenome = engine(10_000).with_batched(false);
        let mut rng = Pcg64::seeded(13);
        let base = batched.eval.spec.random(&mut rng);
        let sg = batched.eval.spec.sg_start;
        // A mixed brood: random genomes plus strategy-only siblings of
        // one parent (the grouping the SoA tables exist for).
        let mut pop: Vec<Vec<u32>> =
            (0..40).map(|_| batched.eval.spec.random(&mut rng)).collect();
        for i in 0..10u32 {
            let mut g = base.clone();
            g[sg] = i % 7;
            pop.push(g);
        }
        let a = batched.eval_batch(&arcs(&pop), None);
        let b = pergenome.eval_batch(&arcs(&pop), None);
        assert_eq!(a, b, "batched SoA assembly diverged from the per-genome walk");
        assert_eq!(batched.stage_hits(), pergenome.stage_hits());
        assert_eq!(batched.stage_misses(), pergenome.stage_misses());
        assert_eq!(batched.cache_sizes(), pergenome.cache_sizes());
        for (g, r) in pop.iter().zip(&a) {
            assert_eq!(*r, batched.eval.eval_genome(g), "batched diverged on {g:?}");
        }
        // Pooled batched dispatch (range chunks over the shared tables)
        // is bit-identical too, warm or cold.
        let mut pooled = engine(10_000);
        let pool = Arc::new(ThreadPool::new(4));
        assert_eq!(pooled.eval_batch(&arcs(&pop), Some(&pool)), a);
        assert_eq!(pooled.eval_batch(&arcs(&pop), Some(&pool)), a);
        assert_eq!(batched.eval_batch(&arcs(&pop), None), a);
    }

    #[test]
    fn capped_engine_degrades_to_scratch_with_identical_results() {
        let mut e = engine(10_000).with_caps(2, 3);
        let mut rng = Pcg64::seeded(11);
        let genomes: Vec<Vec<u32>> = (0..50).map(|_| e.eval.spec.random(&mut rng)).collect();
        let r = e.eval_batch(&arcs(&genomes), None);
        let (maps, fmts) = e.cache_sizes();
        assert!(maps <= 2, "mapping cache exceeded its cap: {maps}");
        assert!(fmts <= 3, "format cache exceeded its cap: {fmts}");
        for (g, r) in genomes.iter().zip(&r) {
            assert_eq!(*r, e.eval.eval_genome(g), "capped path diverged on {g:?}");
        }
        // The degraded mode keeps its parallelism: a pooled capped engine
        // returns the same results.
        let mut pooled = engine(10_000).with_caps(2, 3);
        let pool = Arc::new(ThreadPool::new(4));
        assert_eq!(pooled.eval_batch(&arcs(&genomes), Some(&pool)), r);
    }
}
