//! Structured sparsity patterns — density models beyond a uniform scalar.
//!
//! Real sparse tensors are rarely uniform: pruned weights come in dense
//! blocks, stencil operators are banded, graph tensors have power-law
//! rows. Ranking accelerator designs correctly requires modeling *where*
//! the nonzeros live, not just how many there are (the central lesson of
//! Sparseloop's per-tile density models). This subsystem provides:
//!
//! * [`DensityModel`] — `Uniform` (the legacy scalar), `Block`, `Banded`,
//!   `RowSkewed` and `Measured` patterns, each answering the three
//!   questions the cost model asks: per-slot occupancy probability
//!   ([`DensityModel::slot_prob`], drives compression storage), expected
//!   per-tile nonzeros ([`DensityModel::tile_nonzeros`]) and a
//!   tail-quantile tile occupancy for buffer provisioning
//!   ([`DensityModel::occupancy_quantile`], [`DensityModel::sizing_ratio`]).
//! * [`effectual_frac`] / [`effectual_macs`] — effectual-MAC accounting
//!   for a `P × Q` contraction under two operand patterns.
//! * [`inspect`] — fitting a model to a real tensor file (COO /
//!   MatrixMarket / SMTX), behind `sparsemap inspect-tensor`.
//!
//! Every [`crate::workload::TensorSpec`] carries a `DensityModel`; with
//! `Uniform` the whole stack reproduces the pre-subsystem scalar
//! arithmetic bit-for-bit (enforced by `rust/tests/proptests.rs`), while
//! structured patterns change compression cost, buffer provisioning and
//! therefore search outcomes (`sparsemap patterns`).

pub mod inspect;
pub mod model;

pub use inspect::{fit_model, parse_tensor_text, TensorStats};
pub use model::{
    effectual_frac, effectual_macs, DensityModel, MAX_MEASURED_BUCKETS, SIZING_QUANTILE,
};
