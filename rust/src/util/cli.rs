//! Tiny command-line parser (the vendor set has no `clap`).
//!
//! Grammar: `sparsemap <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`. Unknown keys are
//! reported with the subcommand's usage string.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the subcommand.
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = it.next().unwrap().clone();
                    args.options.insert(stripped.to_string(), val);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_empty() {
                args.subcommand = tok.clone();
            } else {
                args.positional.push(tok.clone());
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["search", "mm3", "extra"]);
        assert_eq!(a.subcommand, "search");
        assert_eq!(a.positional, vec!["mm3", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["search", "--budget=500", "--platform", "cloud"]);
        assert_eq!(a.opt("budget"), Some("500"));
        assert_eq!(a.opt("platform"), Some("cloud"));
        assert_eq!(a.opt_u64("budget", 0).unwrap(), 500);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["table4", "--summary"]);
        assert!(a.flag("summary"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_before_value_option() {
        // --quiet is a flag because the next token is another option.
        let a = parse(&["run", "--quiet", "--seed", "7"]);
        assert!(a.flag("quiet"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["run", "--seed", "x"]);
        assert!(a.opt_u64("seed", 0).is_err());
        assert!(a.opt_f64("seed", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["run"]);
        assert_eq!(a.opt_or("platform", "edge"), "edge");
        assert_eq!(a.opt_u64("budget", 20_000).unwrap(), 20_000);
    }
}
