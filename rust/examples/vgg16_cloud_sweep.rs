//! Fig. 17a-style sweep: SparseMap vs the classical optimizers on the
//! pruned-VGG16 conv layers (cloud platform), reduced budget.
//!
//! ```bash
//! cargo run --release --example vgg16_cloud_sweep -- [budget]
//! ```

use sparsemap::arch::Platform;
use sparsemap::report::{fig17, ExpConfig};
use sparsemap::util::table::{sci, Table};

fn main() -> anyhow::Result<()> {
    let budget: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let cfg = ExpConfig { budget, threads: 8, ..Default::default() };

    let layers = ["conv1", "conv4", "conv7", "conv11", "conv13"];
    println!(
        "VGG16 sweep on cloud: {} methods x {} layers, budget {budget} each",
        fig17::FIG17_METHODS.len(),
        layers.len()
    );
    let outcomes = fig17::run_matrix(&cfg, &Platform::cloud(), &layers);

    let mut table = Table::new(&["layer", "method", "best EDP", "valid %"]);
    for layer in &layers {
        for method in fig17::FIG17_METHODS {
            let o = outcomes
                .iter()
                .find(|o| &o.workload == layer && &o.method == method)
                .unwrap();
            table.row(vec![
                layer.to_string(),
                method.to_string(),
                if o.found_valid() { sci(o.best_edp) } else { "-".into() },
                format!("{:.1}", 100.0 * o.valid_ratio()),
            ]);
        }
    }
    println!("{}", table.render());

    // Count wins.
    let mut wins = 0;
    for layer in &layers {
        let best = outcomes
            .iter()
            .filter(|o| &o.workload == layer)
            .min_by(|a, b| a.best_edp.partial_cmp(&b.best_edp).unwrap())
            .unwrap();
        if best.method == "sparsemap" {
            wins += 1;
        }
        println!("{layer}: winner = {}", best.method);
    }
    println!("sparsemap wins {wins}/{} layers", layers.len());
    Ok(())
}
