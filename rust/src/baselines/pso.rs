//! Particle Swarm Optimization baseline (§III.C).
//!
//! Standard global-best PSO over a continuous relaxation of the *raw*
//! (direct-encoded) design space — see [`super::space`] for why the
//! classical baselines do not get SparseMap's prime-factor encoding.
//! Positions live in `[lo, hi]` per gene and decode by rounding;
//! constants follow Clerc's constriction values.

use super::space::DirectSpace;
use crate::optimizer::checkpoint::{f64s_from_json, f64s_to_json, rng_from_json, rng_to_json};
use crate::optimizer::Optimizer;
use crate::search::{EvalContext, Outcome};
use crate::util::json::{f64_bits, f64_from_bits, Json};
use crate::util::rng::Pcg64;
use anyhow::anyhow;

#[derive(Clone, Copy, Debug)]
pub struct PsoConfig {
    pub swarm: usize,
    pub inertia: f64,
    pub c1: f64,
    pub c2: f64,
}

impl Default for PsoConfig {
    fn default() -> Self {
        PsoConfig { swarm: 40, inertia: 0.729, c1: 1.494, c2: 1.494 }
    }
}

fn decode(pos: &[f64], space: &DirectSpace) -> Vec<u32> {
    (0..space.len()).map(|i| space.snap(i, pos[i])).collect()
}

/// Live swarm state between iterations — everything [`PsoOpt::suspend`]
/// must carry to continue bit-identically.
struct PsoState {
    rng: Pcg64,
    pos: Vec<Vec<f64>>,
    vel: Vec<Vec<f64>>,
    pbest: Vec<Vec<f64>>,
    pbest_cost: Vec<f64>,
    gbest: Vec<f64>,
    gbest_cost: f64,
}

/// PSO as a resumable [`Optimizer`]. The [`DirectSpace`] is rebuilt
/// deterministically from the context + seed on every entry (it consumes
/// no RNG), so only the swarm itself is checkpointed. The legacy
/// [`pso_with`] free function delegates here.
pub struct PsoOpt {
    cfg: PsoConfig,
    st: Option<PsoState>,
}

impl PsoOpt {
    pub fn new(cfg: PsoConfig) -> PsoOpt {
        PsoOpt { cfg, st: None }
    }
}

impl Optimizer for PsoOpt {
    fn label(&self) -> &str {
        "pso"
    }

    fn run(&mut self, ctx: &mut EvalContext, seed: u64) {
        // The registry schema enforces swarm >= 1; floor it here too so a
        // direct caller can't hit the empty-swarm indexing below.
        let cfg = PsoConfig { swarm: self.cfg.swarm.max(1), ..self.cfg };
        let space = DirectSpace::new(ctx, seed);
        let n = space.len();
        let lo: Vec<f64> = (0..n).map(|i| space.bounds(i).0 as f64).collect();
        let hi: Vec<f64> = (0..n).map(|i| space.bounds(i).1 as f64).collect();

        let st = self.st.get_or_insert_with(|| {
            let mut rng = Pcg64::seeded(seed);
            // Positions start at feasible-looking points (small-divisor-
            // biased samples): per-level tile factors multiply up to the
            // dimension, so a uniform start overshoots and the whole swarm
            // would begin dead.
            let pos: Vec<Vec<f64>> = (0..cfg.swarm)
                .map(|_| (0..n).map(|i| space.sample_action(i, &mut rng) as f64).collect())
                .collect();
            let vel: Vec<Vec<f64>> = (0..cfg.swarm)
                .map(|_| (0..n).map(|i| (hi[i] - lo[i]) * (rng.f64() - 0.5) * 0.05).collect())
                .collect();
            let pbest = pos.clone();
            let gbest = pos[0].clone();
            PsoState {
                rng,
                pos,
                vel,
                pbest,
                pbest_cost: vec![f64::INFINITY; cfg.swarm],
                gbest,
                gbest_cost: f64::INFINITY,
            }
        });

        while !ctx.should_pause() {
            let genomes: Vec<Vec<u32>> = st.pos.iter().map(|p| decode(p, &space)).collect();
            let results = space.eval(ctx, &genomes);
            for (i, r) in results.iter().enumerate() {
                let cost = if r.valid { r.edp } else { f64::INFINITY };
                if cost < st.pbest_cost[i] {
                    st.pbest_cost[i] = cost;
                    st.pbest[i] = st.pos[i].clone();
                }
                if cost < st.gbest_cost {
                    st.gbest_cost = cost;
                    st.gbest = st.pos[i].clone();
                }
            }
            if results.len() < cfg.swarm {
                // Budget (or fence) ran out mid-iteration: stop before the
                // velocity update. State is preserved — if this was a
                // fence, a later unfenced re-entry resubmits the same
                // positions (cache-served) and continues.
                break;
            }
            for i in 0..cfg.swarm {
                for d in 0..n {
                    let r1 = st.rng.f64();
                    let r2 = st.rng.f64();
                    st.vel[i][d] = cfg.inertia * st.vel[i][d]
                        + cfg.c1 * r1 * (st.pbest[i][d] - st.pos[i][d])
                        + cfg.c2 * r2 * (st.gbest[d] - st.pos[i][d]);
                    let vmax = (hi[d] - lo[d]) * 0.5;
                    st.vel[i][d] = st.vel[i][d].clamp(-vmax, vmax);
                    st.pos[i][d] = (st.pos[i][d] + st.vel[i][d]).clamp(lo[d], hi[d]);
                }
            }
        }
    }

    fn suspend(&self) -> Option<Json> {
        let vecs = |vv: &[Vec<f64>]| Json::Arr(vv.iter().map(|v| f64s_to_json(v)).collect());
        Some(match &self.st {
            None => Json::obj(vec![("swarm", Json::Null)]),
            Some(st) => Json::obj(vec![(
                "swarm",
                Json::obj(vec![
                    ("rng", rng_to_json(&st.rng)),
                    ("pos", vecs(&st.pos)),
                    ("vel", vecs(&st.vel)),
                    ("pbest", vecs(&st.pbest)),
                    ("pbest_cost", f64s_to_json(&st.pbest_cost)),
                    ("gbest", f64s_to_json(&st.gbest)),
                    ("gbest_cost", f64_bits(st.gbest_cost)),
                ]),
            )]),
        })
    }

    fn resume(&mut self, state: &Json) -> anyhow::Result<()> {
        let swarm = match state.get("swarm") {
            None | Some(Json::Null) => {
                self.st = None;
                return Ok(());
            }
            Some(j) => j,
        };
        let vecs = |key: &str| -> anyhow::Result<Vec<Vec<f64>>> {
            swarm
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("pso state is missing '{key}'"))?
                .iter()
                .map(f64s_from_json)
                .collect()
        };
        self.st = Some(PsoState {
            rng: rng_from_json(
                swarm.get("rng").ok_or_else(|| anyhow!("pso state is missing 'rng'"))?,
            )?,
            pos: vecs("pos")?,
            vel: vecs("vel")?,
            pbest: vecs("pbest")?,
            pbest_cost: f64s_from_json(
                swarm
                    .get("pbest_cost")
                    .ok_or_else(|| anyhow!("pso state is missing 'pbest_cost'"))?,
            )?,
            gbest: f64s_from_json(
                swarm.get("gbest").ok_or_else(|| anyhow!("pso state is missing 'gbest'"))?,
            )?,
            gbest_cost: swarm
                .get("gbest_cost")
                .and_then(f64_from_bits)
                .ok_or_else(|| anyhow!("pso state is missing 'gbest_cost'"))?,
        });
        Ok(())
    }
}

/// Config-parameterized core against a borrowed context (the legacy
/// free-function entry point; telemetry accumulates in `ctx`). One fresh
/// [`PsoOpt`] per call — bit-identical to the pre-trait loop.
pub fn pso_with(ctx: &mut EvalContext, cfg: &PsoConfig, seed: u64) {
    PsoOpt::new(*cfg).run(ctx, seed);
}

pub fn pso(mut ctx: EvalContext, seed: u64) -> Outcome {
    pso_with(&mut ctx, &PsoConfig::default(), seed);
    ctx.outcome("pso")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.3, 0.3);
        EvalContext::new(Backend::native(w, Platform::mobile()), budget)
    }

    #[test]
    fn pso_runs_within_budget() {
        let o = pso(ctx(1_000), 5);
        assert!(o.evals <= 1_000);
        assert_eq!(o.method, "pso");
    }

    #[test]
    fn decode_clamps_to_bounds() {
        let c = ctx(10);
        let space = DirectSpace::new(&c, 1);
        let below = vec![-10.0; space.len()];
        let above = vec![1e9; space.len()];
        for g in [decode(&below, &space), decode(&above, &space)] {
            for (i, &v) in g.iter().enumerate() {
                let (lo, hi) = space.bounds(i);
                assert!(v >= lo && v <= hi, "gene {i} value {v} not in [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn pso_struggles_with_raw_space_validity() {
        // The paper's point: classical optimizers waste most of the
        // budget on invalid (tiling-violating) points.
        let o = pso(ctx(2_000), 6);
        assert!(o.valid_ratio() < 0.6, "valid ratio {}", o.valid_ratio());
    }
}
