//! Genome specification: gene layout, ranges and segment structure for a
//! given workload (Fig. 13 top row, Fig. 15 for multi-dimensional
//! workloads).
//!
//! Layout (left to right):
//! * `perm1..perm5` — Cantor codes, one per mapping level, range `[1, D!]`;
//! * one *prime-factor* gene per prime factor of every (padded) dimension,
//!   range `[1, 5]` — the mapping level the factor is assigned to;
//! * `P0..P4, Q0..Q4, Z0..Z4` — per-rank compression formats, range `[0,4]`;
//! * `SG_L2, SG_L3, SG_C` — skip/gate mechanism per site, range `[0,6]`.

use crate::mapping::permutation::factorial;
use crate::mapping::NUM_MAP_LEVELS;
use crate::sparse::{NUM_RANK_FORMATS, NUM_SG_CHOICES};
use crate::util::rng::Pcg64;
use crate::workload::Workload;

/// Number of format genes per tensor (fixed, §IV.F).
pub const FORMAT_GENES_PER_TENSOR: usize = 5;
/// Number of S/G sites (GLB, PE buffer, compute).
pub const SG_SITES: usize = 3;

/// What a gene position encodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneKind {
    /// Permutation of mapping level `level` (0..5).
    Perm { level: usize },
    /// `idx`-th prime factor of dimension `dim`.
    Factor { dim: usize, idx: usize, prime: u64 },
    /// Format slot `slot` (0..5) of tensor `tensor` (0=P,1=Q,2=Z).
    Format { tensor: usize, slot: usize },
    /// S/G gene of site `site` (0=GLB/L2, 1=PEBuf/L3, 2=Compute).
    Sg { site: usize },
}

/// Inclusive value range of a gene.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GeneRange {
    pub lo: u32,
    pub hi: u32,
}

impl GeneRange {
    pub fn width(&self) -> u32 {
        self.hi - self.lo + 1
    }

    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        rng.range_u32(self.lo, self.hi)
    }

    pub fn clamp_wrap(&self, v: u32) -> u32 {
        self.lo + (v.saturating_sub(self.lo)) % self.width()
    }
}

/// Genome layout for one workload.
#[derive(Clone, Debug)]
pub struct GenomeSpec {
    pub kinds: Vec<GeneKind>,
    pub ranges: Vec<GeneRange>,
    /// Gene index where the factor segment starts (== NUM_MAP_LEVELS).
    pub factor_start: usize,
    /// Gene index where the format segment starts.
    pub format_start: usize,
    /// Gene index where the S/G segment starts.
    pub sg_start: usize,
    /// Iteration-space rank D.
    pub rank: usize,
}

impl GenomeSpec {
    pub fn for_workload(w: &Workload) -> GenomeSpec {
        let d = w.rank();
        let perm_hi = factorial(d) as u32;
        let mut kinds = Vec::new();
        let mut ranges = Vec::new();

        for level in 0..NUM_MAP_LEVELS {
            kinds.push(GeneKind::Perm { level });
            ranges.push(GeneRange { lo: 1, hi: perm_hi });
        }
        let factor_start = kinds.len();
        for (dim, dspec) in w.dims.iter().enumerate() {
            for (idx, &prime) in dspec.factors.iter().enumerate() {
                kinds.push(GeneKind::Factor { dim, idx, prime });
                ranges.push(GeneRange { lo: 1, hi: NUM_MAP_LEVELS as u32 });
            }
        }
        let format_start = kinds.len();
        for tensor in 0..3 {
            for slot in 0..FORMAT_GENES_PER_TENSOR {
                kinds.push(GeneKind::Format { tensor, slot });
                ranges.push(GeneRange { lo: 0, hi: NUM_RANK_FORMATS - 1 });
            }
        }
        let sg_start = kinds.len();
        for site in 0..SG_SITES {
            kinds.push(GeneKind::Sg { site });
            ranges.push(GeneRange { lo: 0, hi: NUM_SG_CHOICES - 1 });
        }

        GenomeSpec { kinds, ranges, factor_start, format_start, sg_start, rank: d }
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Sample a uniformly random genome (every gene independently within
    /// its range). Note: always satisfies dimension-tiling constraints by
    /// construction — the point of prime-factor encoding.
    pub fn random(&self, rng: &mut Pcg64) -> Vec<u32> {
        self.ranges.iter().map(|r| r.sample(rng)).collect()
    }

    /// Check a genome is structurally in-range.
    pub fn in_range(&self, genome: &[u32]) -> bool {
        genome.len() == self.len()
            && genome.iter().zip(&self.ranges).all(|(&g, r)| g >= r.lo && g <= r.hi)
    }

    /// Repair out-of-range genes by wrapping into range (used after
    /// unconstrained mutation).
    pub fn repair(&self, genome: &mut [u32]) {
        for (g, r) in genome.iter_mut().zip(&self.ranges) {
            if *g < r.lo || *g > r.hi {
                *g = r.clamp_wrap(*g);
            }
        }
    }

    /// Size of the *encoded* search space: product of gene range widths.
    /// Returned as log10 to avoid overflow (the paper quotes O(10^41)-
    /// class joint spaces for direct encodings; ours is much smaller).
    pub fn log10_space(&self) -> f64 {
        self.ranges.iter().map(|r| (r.width() as f64).log10()).sum()
    }

    /// The mapping segment (permutation + prime-factor genes) of a
    /// genome — the input of [`crate::genome::decode_mapping`] and the
    /// key of the evaluation engine's mapping-stage cache.
    pub fn mapping_genes<'a>(&self, genome: &'a [u32]) -> &'a [u32] {
        &genome[..self.format_start]
    }

    /// The [`FORMAT_GENES_PER_TENSOR`] format genes of tensor `t`.
    pub fn format_genes<'a>(&self, genome: &'a [u32], t: usize) -> &'a [u32] {
        &genome[self.format_start + t * FORMAT_GENES_PER_TENSOR..][..FORMAT_GENES_PER_TENSOR]
    }

    /// The [`SG_SITES`] skip/gate genes.
    pub fn sg_genes<'a>(&self, genome: &'a [u32]) -> &'a [u32] {
        &genome[self.sg_start..][..SG_SITES]
    }

    /// Natural segment boundaries used by sensitivity-aware crossover:
    /// [perm | factors | formats | sg] plus per-tensor format boundaries.
    pub fn segment_boundaries(&self) -> Vec<usize> {
        let mut b = vec![
            self.factor_start,
            self.format_start,
            self.format_start + FORMAT_GENES_PER_TENSOR,
            self.format_start + 2 * FORMAT_GENES_PER_TENSOR,
            self.sg_start,
        ];
        b.dedup();
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> (Workload, GenomeSpec) {
        let w = Workload::spmm("t", 4, 8, 4, 0.5, 0.5);
        let s = GenomeSpec::for_workload(&w);
        (w, s)
    }

    #[test]
    fn layout_lengths() {
        let (w, s) = spec();
        // 5 perms + 7 factors (2+3+2) + 15 formats + 3 sg = 30.
        assert_eq!(s.len(), 5 + w.num_factor_genes() + 15 + 3);
        assert_eq!(s.factor_start, 5);
        assert_eq!(s.format_start, 12);
        assert_eq!(s.sg_start, 27);
    }

    #[test]
    fn perm_range_depends_on_rank() {
        let (_, s) = spec();
        assert_eq!(s.ranges[0], GeneRange { lo: 1, hi: 6 }); // 3! = 6
        let wb = Workload::spbmm("b", 2, 4, 4, 4, 0.5, 0.5);
        let sb = GenomeSpec::for_workload(&wb);
        assert_eq!(sb.ranges[0], GeneRange { lo: 1, hi: 24 }); // 4! = 24
    }

    #[test]
    fn random_always_in_range() {
        let (_, s) = spec();
        let mut rng = Pcg64::seeded(1);
        for _ in 0..200 {
            let g = s.random(&mut rng);
            assert!(s.in_range(&g));
        }
    }

    #[test]
    fn repair_wraps() {
        let (_, s) = spec();
        let mut g = s.random(&mut Pcg64::seeded(2));
        g[0] = 99; // perm out of range
        g[s.sg_start] = 100;
        assert!(!s.in_range(&g));
        s.repair(&mut g);
        assert!(s.in_range(&g));
    }

    #[test]
    fn space_size_reasonable() {
        let (_, s) = spec();
        // 6^5 * 5^7 * 5^15 * 7^3 ≈ 10^19.6 — large but far below the
        // direct-value encoding the paper criticizes.
        let l = s.log10_space();
        assert!(l > 15.0 && l < 25.0, "log10 space = {l}");
    }

    #[test]
    fn factor_genes_carry_primes() {
        let (w, s) = spec();
        let mut count = 0;
        for k in &s.kinds {
            if let GeneKind::Factor { dim, prime, .. } = k {
                assert!(w.dims[*dim].factors.contains(prime));
                count += 1;
            }
        }
        assert_eq!(count, 7);
    }

    #[test]
    fn segment_accessors_partition_the_genome() {
        let (_, s) = spec();
        let g: Vec<u32> = (0..s.len() as u32).collect();
        let mut rebuilt = s.mapping_genes(&g).to_vec();
        for t in 0..3 {
            rebuilt.extend_from_slice(s.format_genes(&g, t));
        }
        rebuilt.extend_from_slice(s.sg_genes(&g));
        assert_eq!(rebuilt, g, "segments must tile the genome exactly");
    }

    #[test]
    fn segment_boundaries_sorted_unique() {
        let (_, s) = spec();
        let b = s.segment_boundaries();
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        assert!(b.contains(&s.factor_start));
        assert!(b.contains(&s.sg_start));
    }
}
