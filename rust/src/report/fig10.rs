//! E3 / Fig. 10 — Cantor vs random permutation encoding.
//!
//! Both arms run the same ES on the same PFCE genome; the *random* arm
//! decodes permutation genes through a scrambled bijection on `[1, D!]`
//! before evaluation, destroying the gene-distance ↔ permutation-distance
//! correlation that Cantor encoding provides. The claim to reproduce:
//! the Cantor arm converges faster / lower.

use super::{write_csv, ExpConfig};
use crate::arch::Platform;
use crate::genome::ops;
use crate::mapping::permutation::factorial;
use crate::search::{EvalContext, Outcome};
use crate::util::rng::Pcg64;
use crate::workload::table3;

/// Scramble: a seeded bijection on permutation codes.
fn scramble_table(d: usize, seed: u64) -> Vec<u32> {
    let n = factorial(d) as usize;
    let mut t: Vec<u32> = (1..=n as u32).collect();
    let mut rng = Pcg64::new(seed, 0x5c7a);
    rng.shuffle(&mut t);
    t
}

/// A compact ES (one-point crossover + point mutation) whose genomes pass
/// through `transform` before evaluation.
fn run_es(
    mut ctx: EvalContext,
    seed: u64,
    method: &str,
    transform: impl Fn(&[u32]) -> Vec<u32>,
) -> Outcome {
    let spec = ctx.spec.clone();
    let mut rng = Pcg64::seeded(seed);
    let pop_size = 50;

    let mut genomes: Vec<Vec<u32>> = (0..pop_size).map(|_| spec.random(&mut rng)).collect();
    let mut pop: Vec<(Vec<u32>, f64)> = Vec::new();
    let evaluated: Vec<Vec<u32>> = genomes.iter().map(|g| transform(g)).collect();
    for (g, r) in genomes.drain(..).zip(ctx.eval_batch(&evaluated)) {
        pop.push((g, if r.valid { 1.0 / r.edp } else { 0.0 }));
    }
    while !ctx.exhausted() {
        pop.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        pop.truncate((pop_size / 4).max(2));
        let mut children = Vec::with_capacity(pop_size);
        while children.len() < pop_size {
            let pa = &pop[rng.index(pop.len())].0;
            let pb = &pop[rng.index(pop.len())].0;
            let (mut c, _) = ops::onepoint_crossover(pa, pb, &mut rng);
            if rng.chance(0.7) {
                // Local moves: ±1 nudges are exactly where encoding
                // locality matters (Fig. 10's argument).
                let i = rng.index(spec.len());
                ops::nudge_gene(&spec, &mut c, i, &mut rng);
            }
            children.push(c);
        }
        let evaluated: Vec<Vec<u32>> = children.iter().map(|g| transform(g)).collect();
        let results = ctx.eval_batch(&evaluated);
        if results.is_empty() {
            break;
        }
        for (g, r) in children.into_iter().zip(results) {
            pop.push((g, if r.valid { 1.0 / r.edp } else { 0.0 }));
        }
    }
    ctx.outcome(method)
}

/// Run both arms; returns (cantor, random).
pub fn run_arms(cfg: &ExpConfig) -> (Outcome, Outcome) {
    let w = table3::by_id("mm3").expect("mm3");
    let plat = Platform::cloud();

    let cantor = run_es(
        cfg.context(w.clone(), plat.clone()),
        cfg.seed,
        "cantor-encoding",
        |g| g.to_vec(),
    );

    let d = w.rank();
    let table = scramble_table(d, cfg.seed);
    let random = run_es(
        cfg.context(w, plat),
        cfg.seed,
        "random-encoding",
        move |g| {
            let mut out = g.to_vec();
            for lvl in 0..5 {
                out[lvl] = table[(g[lvl] as usize - 1) % table.len()];
            }
            out
        },
    );
    (cantor, random)
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<String> {
    let (cantor, random) = run_arms(cfg);
    let mut csv = String::from("arm,evals,best_edp\n");
    for o in [&cantor, &random] {
        for &(e, v) in &o.curve {
            csv.push_str(&format!("{},{},{:.6e}\n", o.method, e, v));
        }
    }
    write_csv(&cfg.out_dir, "fig10.csv", &csv)?;
    Ok(format!(
        "Fig. 10 — permutation encoding (mm3 @ cloud, budget {})\n\
         cantor-encoding : best EDP {:.4e}  (valid ratio {:.1}%)\n\
         random-encoding : best EDP {:.4e}  (valid ratio {:.1}%)\n\
         cantor/random improvement: {:.2}x\n",
        cfg.budget,
        cantor.best_edp,
        100.0 * cantor.valid_ratio(),
        random.best_edp,
        100.0 * random.valid_ratio(),
        random.best_edp / cantor.best_edp
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scramble_is_bijection() {
        let t = scramble_table(3, 7);
        let mut s = t.clone();
        s.sort_unstable();
        assert_eq!(s, (1..=6).collect::<Vec<u32>>());
        assert_ne!(t, (1..=6).collect::<Vec<u32>>()); // actually scrambled
    }

    #[test]
    fn both_arms_complete_within_budget() {
        let cfg = ExpConfig { budget: 1_200, seed: 5, ..Default::default() };
        let (c, r) = run_arms(&cfg);
        assert!(c.evals <= 1_200 && r.evals <= 1_200);
        assert!(c.found_valid());
        assert!(r.found_valid());
    }

    #[test]
    fn cantor_not_worse_than_random_encoding() {
        // Median over 3 seeds to damp noise; the paper's Fig. 10c shows a
        // consistent gap at equal budget.
        let mut wins = 0;
        for seed in [11, 12, 13] {
            let cfg = ExpConfig { budget: 2_000, seed, ..Default::default() };
            let (c, r) = run_arms(&cfg);
            if c.best_edp <= r.best_edp * 1.05 {
                wins += 1;
            }
        }
        assert!(wins >= 2, "cantor won only {wins}/3 seeds");
    }
}
