//! [`SearchReport`] — the typed result of one search arm, with a full
//! JSON round-trip.

use super::request::SearchRequest;
use crate::search::Outcome;
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Result};

/// Schema tag stamped into every serialized report.
pub const REPORT_SCHEMA: &str = "sparsemap.report.v2";

/// The previous schema tag. [`SearchReport::from_json`] still accepts
/// reports stamped with it, byte-identical to how they were written
/// (pinned by the committed `rust/tests/golden/report_v1.json` fixture);
/// the v1 form simply never carries `checkpoint` / `resumed_from`.
pub const REPORT_SCHEMA_V1: &str = "sparsemap.search_report.v1";

/// The result of one search arm: the validated request it answered, the
/// full search outcome (best EDP/genome, convergence curve, budget
/// accounting) and run metadata. Serializes losslessly with
/// [`SearchReport::to_json`] / [`SearchReport::from_json`].
#[derive(Clone, Debug)]
pub struct SearchReport {
    /// The request this report answers (echoed for provenance).
    pub request: SearchRequest,
    pub outcome: Outcome,
    /// Wall-clock seconds the run took.
    pub wall_s: f64,
    /// Whether an observer, cancel token or suspend flag ended the run
    /// before the budget was spent.
    pub stopped_early: bool,
    /// When the run was suspended mid-search: a serialized
    /// [`crate::optimizer::Checkpoint`] that resumes it (pass back
    /// through `RunOpts::resume`). `None` for completed runs.
    pub checkpoint: Option<Json>,
    /// When this run resumed from a checkpoint: the number of
    /// evaluations that were already spent at the resume point.
    pub resumed_from: Option<usize>,
}

impl SearchReport {
    /// Genomes actually sent to the cost model (submissions minus cache
    /// hits).
    pub fn model_evals(&self) -> usize {
        self.outcome.evals - self.outcome.cache_hits
    }

    /// Model evaluations per second actually paid for.
    pub fn model_evals_per_s(&self) -> f64 {
        self.model_evals() as f64 / self.wall_s.max(1e-9)
    }

    /// Distinct genomes the evaluation engine interned — the cache-key
    /// working set of the run.
    pub fn distinct_genomes(&self) -> usize {
        self.outcome.interned
    }

    /// Stage-level cache hits (see `search::engine`): how much of the
    /// population's structure the staged cache exploited. One evaluation
    /// can contribute up to 4 hits (its mapping stage + three per-tensor
    /// format stages), so this can legitimately exceed `evals`.
    pub fn stage_hits(&self) -> usize {
        self.outcome.stage_hits
    }

    /// Per-member budget/best breakdown — non-empty only for the
    /// `portfolio` meta-method (see `crate::optimizer::portfolio`).
    pub fn members(&self) -> &[crate::search::MemberStats] {
        &self.outcome.members
    }

    /// How many design-memory genomes seeded this run's initial
    /// population (0 unless the request carried a `warm_start` block and
    /// the store held usable neighbours — see [`crate::memory`]).
    pub fn memory_hits(&self) -> usize {
        self.outcome.memory_hits
    }

    /// Scenario tags the warm-start seeds came from, nearest first
    /// (empty when warm-start is off).
    pub fn seeded_from(&self) -> &[String] {
        &self.outcome.seeded_from
    }

    pub fn into_outcome(self) -> Outcome {
        self.outcome
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("schema", Json::str(REPORT_SCHEMA)),
            ("request", self.request.to_json()),
            ("outcome", self.outcome.to_json_full()),
            ("wall_s", Json::num(self.wall_s)),
            ("stopped_early", Json::Bool(self.stopped_early)),
        ]);
        // Completed, non-resumed reports keep the exact v1 key set (only
        // the schema tag moved), so diffs against archived reports stay
        // readable.
        if let Json::Obj(o) = &mut j {
            if let Some(cp) = &self.checkpoint {
                o.insert("checkpoint".to_string(), cp.clone());
            }
            if let Some(evals) = self.resumed_from {
                o.insert("resumed_from".to_string(), Json::num(evals as f64));
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<SearchReport> {
        if let Some(schema) = j.get("schema").and_then(Json::as_str) {
            ensure!(
                schema == REPORT_SCHEMA || schema == REPORT_SCHEMA_V1,
                "unsupported report schema '{schema}'"
            );
        }
        Ok(SearchReport {
            request: SearchRequest::from_json(
                j.get("request").ok_or_else(|| anyhow!("report JSON is missing 'request'"))?,
            )?,
            outcome: Outcome::from_json(
                j.get("outcome").ok_or_else(|| anyhow!("report JSON is missing 'outcome'"))?,
            )?,
            wall_s: j.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0),
            stopped_early: j.get("stopped_early").and_then(Json::as_bool).unwrap_or(false),
            checkpoint: match j.get("checkpoint") {
                None | Some(Json::Null) => None,
                Some(cp) => Some(cp.clone()),
            },
            resumed_from: j
                .get("resumed_from")
                .and_then(Json::as_u64)
                .map(|e| e as usize),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_round_trips() {
        let report = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("edge")
            .method("random")
            .budget(80)
            .seed(5)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let dumped = report.to_json().pretty();
        let parsed = SearchReport::from_json(&Json::parse(&dumped).unwrap()).unwrap();
        assert_eq!(parsed.request, report.request);
        assert_eq!(parsed.outcome.best_edp, report.outcome.best_edp);
        assert_eq!(parsed.outcome.best_genome, report.outcome.best_genome);
        assert_eq!(parsed.outcome.curve, report.outcome.curve);
        assert_eq!(parsed.stopped_early, report.stopped_early);
        assert_eq!(parsed.distinct_genomes(), report.distinct_genomes());
        assert_eq!(parsed.stage_hits(), report.stage_hits());
        assert_eq!(parsed.to_json(), report.to_json());
    }

    #[test]
    fn portfolio_report_round_trips_with_members() {
        let report = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("edge")
            .method("portfolio")
            .method_opts(Json::parse(r#"{"members": ["random", "pso"], "rounds": 2}"#).unwrap())
            .budget(200)
            .seed(3)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.outcome.method, "portfolio");
        assert_eq!(report.members().len(), 2);
        assert_eq!(report.members().iter().map(|m| m.evals).sum::<usize>(), report.outcome.evals);
        let parsed =
            SearchReport::from_json(&Json::parse(&report.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(parsed.request, report.request);
        assert_eq!(parsed.outcome.members, report.outcome.members);
        assert_eq!(parsed.to_json(), report.to_json());
    }

    #[test]
    fn wrong_schema_rejected() {
        let j = Json::obj(vec![("schema", Json::str("bogus.v9"))]);
        assert!(SearchReport::from_json(&j).is_err());
    }

    #[test]
    fn v1_legacy_report_fixture_still_parses() {
        // A byte-identical report as written by the v1 schema, committed
        // as a golden fixture: upgrading the schema tag must never strand
        // archived reports.
        let text = include_str!("../../tests/golden/report_v1.json");
        let report = SearchReport::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(report.outcome.method, "random");
        assert_eq!(report.outcome.workload, "mm1");
        assert_eq!(report.outcome.evals, 80);
        assert_eq!(report.outcome.best_genome.as_deref(), Some(&[1, 2, 3, 0, 4][..]));
        assert!(!report.stopped_early);
        assert!(report.checkpoint.is_none(), "v1 reports never carry a checkpoint");
        assert!(report.resumed_from.is_none());
        // Re-serialized it carries the current tag, and still round-trips.
        let j = report.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(REPORT_SCHEMA));
        let again = SearchReport::from_json(&Json::parse(&j.dumps()).unwrap()).unwrap();
        assert_eq!(again.to_json(), j);
    }

    #[test]
    fn checkpoint_fields_round_trip() {
        let mut report = SearchRequest::new()
            .workload_named("mm1")
            .platform_named("edge")
            .method("random")
            .budget(40)
            .seed(2)
            .build()
            .unwrap()
            .run()
            .unwrap();
        report.checkpoint =
            Some(Json::obj(vec![("schema", Json::str("sparsemap.checkpoint.v1"))]));
        report.resumed_from = Some(17);
        let dumped = report.to_json().dumps();
        assert!(dumped.contains("checkpoint"));
        let parsed = SearchReport::from_json(&Json::parse(&dumped).unwrap()).unwrap();
        assert_eq!(parsed.checkpoint, report.checkpoint);
        assert_eq!(parsed.resumed_from, Some(17));
        assert_eq!(parsed.to_json(), report.to_json());
    }
}
