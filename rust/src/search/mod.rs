//! Shared search infrastructure: evaluation backends, budget accounting,
//! the parallel/memoizing evaluation pipeline and telemetry (best-so-far
//! curves, valid-point ratios — the raw data behind Fig. 17b and Fig. 18).
//!
//! ## Parallel evaluation
//!
//! An [`EvalContext`] optionally carries a shared
//! [`ThreadPool`](crate::util::threadpool::ThreadPool). Native-model
//! batches are chunked across the pool with the order-preserving
//! `parallel_map`; because the cost model is pure and results are
//! re-assembled in submission order, search trajectories are bit-identical
//! between 1 and N threads. The PJRT backend keeps its own internal
//! batching and ignores the pool.
//!
//! ## Evaluation cache and budget semantics
//!
//! ES populations re-produce identical offspring constantly. The context
//! memoizes results by genome: a repeated genome (within a batch or across
//! generations) is served from the cache without touching the model, but
//! **still debits one evaluation from the sample budget** — the paper's
//! 20 000-sample budget counts *submissions*, not distinct designs, so
//! cached arms stay comparable with uncached ones. Because the model is
//! deterministic, caching never changes a trajectory, only its wall-clock
//! cost. The cache is bounded by the budget (only misses insert entries).

pub mod telemetry;

pub use telemetry::{Outcome, Telemetry};

use crate::arch::Platform;
use crate::genome::Design;
use crate::model::{EvalResult, NativeEvaluator};
#[cfg(feature = "xla")]
use crate::runtime::{BatchEvaluator, Runtime};
use crate::util::threadpool::{parallel_map, ThreadPool};
use crate::workload::Workload;
#[cfg(feature = "xla")]
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Progress snapshot streamed to a [`SearchObserver`] after every
/// evaluated batch (≈ one generation for population algorithms). Carries
/// the live telemetry the Fig. 17b/18 curves are built from.
#[derive(Clone, Debug, PartialEq)]
pub struct Progress {
    /// Batches evaluated so far — a generation proxy.
    pub batches: usize,
    /// Budget submissions spent so far.
    pub evals: usize,
    pub valid_evals: usize,
    /// Submissions served from the evaluation cache.
    pub cache_hits: usize,
    /// Best valid EDP so far (`f64::INFINITY` until one is found).
    pub best_edp: f64,
    /// Total sample budget of the run.
    pub budget: usize,
}

/// What a [`SearchObserver`] wants the search to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchControl {
    Continue,
    /// Stop early: the context reports an exhausted budget from now on,
    /// so every algorithm winds down through its normal exit path.
    Stop,
}

/// Streaming callback attached to an [`EvalContext`] (see
/// [`EvalContext::with_observer`]). Every search algorithm funnels its
/// evaluations through the context, so observers work uniformly across
/// SparseMap and all baselines without per-algorithm wiring.
pub trait SearchObserver: Send {
    fn on_batch(&mut self, progress: &Progress) -> SearchControl;
}

impl<F: FnMut(&Progress) -> SearchControl + Send> SearchObserver for F {
    fn on_batch(&mut self, progress: &Progress) -> SearchControl {
        self(progress)
    }
}

/// Fitness backend: the native Rust model or the PJRT AOT executable.
/// Both implement the same FEATURE_SCHEMA_V1 formula. The native evaluator
/// is shared behind an `Arc` so batches can fan out across worker threads.
pub enum Backend {
    Native(Arc<NativeEvaluator>),
    #[cfg(feature = "xla")]
    Pjrt(Box<BatchEvaluator>),
}

/// Split `n` items so each of `workers` threads sees several chunks (for
/// load balancing) without paying per-item channel overhead.
fn chunk_size(n: usize, workers: usize) -> usize {
    (n / (workers * 4)).max(1)
}

/// A submission slot: either a cached result or an index into the
/// first-occurrence-ordered miss list.
type Slot = std::result::Result<EvalResult, usize>;

/// Resolve a batch of cache keys against `cache` (shared by `eval_batch`
/// and `eval_designs` so the budget/hit semantics cannot diverge).
/// Returns per-submission slots, the key indices that must be evaluated
/// (deduplicated, first occurrence kept), and the hit count.
fn resolve_cache(
    cache: &HashMap<Vec<u32>, EvalResult>,
    enabled: bool,
    keys: &[Vec<u32>],
) -> (Vec<Slot>, Vec<usize>, usize) {
    let mut slots: Vec<Slot> = Vec::with_capacity(keys.len());
    let mut miss_idx: Vec<usize> = Vec::new();
    let mut pending: HashMap<&[u32], usize> = HashMap::new();
    let mut hits = 0usize;
    for (i, g) in keys.iter().enumerate() {
        if enabled {
            if let Some(&r) = cache.get(g.as_slice()) {
                slots.push(Ok(r));
                hits += 1;
                continue;
            }
            if let Some(&j) = pending.get(g.as_slice()) {
                slots.push(Err(j));
                hits += 1;
                continue;
            }
            pending.insert(g.as_slice(), miss_idx.len());
        }
        slots.push(Err(miss_idx.len()));
        miss_idx.push(i);
    }
    (slots, miss_idx, hits)
}

/// Re-assemble per-submission results from slots + evaluated misses.
fn assemble(slots: Vec<Slot>, miss_results: &[EvalResult]) -> Vec<EvalResult> {
    slots
        .into_iter()
        .map(|s| match s {
            Ok(r) => r,
            Err(i) => miss_results[i],
        })
        .collect()
}

impl Backend {
    pub fn native(workload: Workload, platform: Platform) -> Backend {
        Backend::Native(Arc::new(NativeEvaluator::new(workload, platform)))
    }

    #[cfg(feature = "xla")]
    pub fn pjrt(rt: &Runtime, workload: Workload, platform: Platform) -> Result<Backend> {
        Ok(Backend::Pjrt(Box::new(BatchEvaluator::new(rt, workload, platform)?)))
    }

    pub fn workload(&self) -> &Workload {
        match self {
            Backend::Native(e) => &e.workload,
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => &e.workload,
        }
    }

    pub fn platform(&self) -> &Platform {
        match self {
            Backend::Native(e) => &e.platform,
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => &e.platform,
        }
    }

    /// Evaluate genomes, fanning the native model out over `pool` when one
    /// is attached. Results are always in submission order.
    fn eval(&self, pool: Option<&Arc<ThreadPool>>, genomes: &[Vec<u32>]) -> Vec<EvalResult> {
        match self {
            Backend::Native(e) => match pool {
                Some(pool) if pool.size() > 1 && genomes.len() > 1 => {
                    let jobs: Vec<Vec<Vec<u32>>> = genomes
                        .chunks(chunk_size(genomes.len(), pool.size()))
                        .map(|c| c.to_vec())
                        .collect();
                    let ev = Arc::clone(e);
                    parallel_map(pool, jobs, move |chunk| {
                        chunk.iter().map(|g| ev.eval_genome(g)).collect::<Vec<_>>()
                    })
                    .into_iter()
                    .flatten()
                    .collect()
                }
                _ => genomes.iter().map(|g| e.eval_genome(g)).collect(),
            },
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => e
                .eval_genomes(genomes)
                .expect("PJRT evaluation failed (artifact/runtime error)"),
        }
    }

    /// Evaluate pre-decoded designs (`None` = dead on arrival), fanning
    /// out over `pool` like [`Backend::eval`].
    fn eval_designs_batch(
        &self,
        pool: Option<&Arc<ThreadPool>>,
        designs: Vec<Option<Design>>,
    ) -> Vec<EvalResult> {
        match self {
            Backend::Native(e) => match pool {
                Some(pool) if pool.size() > 1 && designs.len() > 1 => {
                    let jobs: Vec<Vec<Option<Design>>> = designs
                        .chunks(chunk_size(designs.len(), pool.size()))
                        .map(|c| c.to_vec())
                        .collect();
                    let ev = Arc::clone(e);
                    parallel_map(pool, jobs, move |chunk| {
                        chunk
                            .into_iter()
                            .map(|d| match d {
                                Some(d) => ev.eval_design(&d),
                                None => EvalResult::dead(),
                            })
                            .collect::<Vec<_>>()
                    })
                    .into_iter()
                    .flatten()
                    .collect()
                }
                _ => designs
                    .into_iter()
                    .map(|d| match d {
                        Some(d) => e.eval_design(&d),
                        None => EvalResult::dead(),
                    })
                    .collect(),
            },
            #[cfg(feature = "xla")]
            Backend::Pjrt(e) => designs
                .into_iter()
                .map(|d| match d {
                    Some(d) => e
                        .eval_designs(std::slice::from_ref(&d))
                        .expect("PJRT evaluation failed")
                        .pop()
                        .unwrap(),
                    None => EvalResult::dead(),
                })
                .collect(),
        }
    }
}

/// A budgeted evaluation context handed to every search algorithm.
///
/// All algorithms draw from the same sample budget (the paper's 20 000)
/// and report through the same telemetry, which keeps comparisons fair.
/// The context also owns the parallel/memoizing pipeline: attach a worker
/// pool with [`EvalContext::with_pool`] and every batch — from SparseMap
/// itself or any baseline — fans out transparently.
pub struct EvalContext {
    backend: Backend,
    pub spec: crate::genome::GenomeSpec,
    pub budget: usize,
    pub telemetry: Telemetry,
    pool: Option<Arc<ThreadPool>>,
    cache_enabled: bool,
    genome_cache: HashMap<Vec<u32>, EvalResult>,
    design_cache: HashMap<Vec<u32>, EvalResult>,
    model_calls: usize,
    observer: Option<Box<dyn SearchObserver>>,
    /// Shared halt flag: set by an observer's [`SearchControl::Stop`] or
    /// externally (cancellation); once set, `remaining()` reports 0.
    stop_flag: Option<Arc<AtomicBool>>,
    stopped: bool,
    batches: usize,
}

impl EvalContext {
    pub fn new(backend: Backend, budget: usize) -> EvalContext {
        let spec = crate::genome::GenomeSpec::for_workload(backend.workload());
        EvalContext {
            backend,
            spec,
            budget,
            telemetry: Telemetry::new(),
            pool: None,
            cache_enabled: true,
            genome_cache: HashMap::new(),
            design_cache: HashMap::new(),
            model_calls: 0,
            observer: None,
            stop_flag: None,
            stopped: false,
            batches: 0,
        }
    }

    /// Attach (or detach) a worker pool for native batch evaluation.
    pub fn with_pool(mut self, pool: Option<Arc<ThreadPool>>) -> EvalContext {
        self.pool = pool;
        self
    }

    /// In-place variant of [`EvalContext::with_pool`].
    pub fn set_pool(&mut self, pool: Option<Arc<ThreadPool>>) {
        self.pool = pool;
    }

    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// Worker threads evaluation fans out over (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.size())
    }

    /// Enable/disable the evaluation cache (on by default). Disabling is
    /// only useful for raw-throughput measurements; results never change.
    pub fn with_cache(mut self, enabled: bool) -> EvalContext {
        self.cache_enabled = enabled;
        self
    }

    /// Attach a streaming [`SearchObserver`], called after every batch.
    /// Observers only *read* progress and can request an early stop —
    /// they never perturb a trajectory that runs to completion.
    pub fn with_observer(mut self, observer: Option<Box<dyn SearchObserver>>) -> EvalContext {
        self.observer = observer;
        self
    }

    /// In-place variant of [`EvalContext::with_observer`].
    pub fn set_observer(&mut self, observer: Option<Box<dyn SearchObserver>>) {
        self.observer = observer;
    }

    /// Attach a shared halt flag. Setting it (from any thread) cancels
    /// the search: the context reports an exhausted budget and every
    /// algorithm winds down through its normal exit path.
    pub fn with_stop_flag(mut self, flag: Option<Arc<AtomicBool>>) -> EvalContext {
        self.stop_flag = flag;
        self
    }

    /// Did an observer or the halt flag stop this run before the budget?
    pub fn stopped_early(&self) -> bool {
        self.stopped || self.stop_flag.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// Batches evaluated so far (the observer's generation proxy).
    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Bump batch count and notify the observer, honoring its verdict.
    fn finish_batch(&mut self) {
        self.batches += 1;
        if let Some(obs) = self.observer.as_mut() {
            let progress = Progress {
                batches: self.batches,
                evals: self.telemetry.evals,
                valid_evals: self.telemetry.valid_evals,
                cache_hits: self.telemetry.cache_hits,
                best_edp: self.telemetry.best_edp,
                budget: self.budget,
            };
            if obs.on_batch(&progress) == SearchControl::Stop {
                self.stopped = true;
                if let Some(f) = &self.stop_flag {
                    f.store(true, Ordering::SeqCst);
                }
            }
        }
    }

    /// Number of genomes actually sent to the model so far (submissions
    /// minus cache hits minus dead-on-arrival designs).
    pub fn model_calls(&self) -> usize {
        self.model_calls
    }

    /// Submissions served from the cache so far.
    pub fn cache_hits(&self) -> usize {
        self.telemetry.cache_hits
    }

    pub fn workload(&self) -> &Workload {
        self.backend.workload()
    }

    pub fn platform(&self) -> &Platform {
        self.backend.platform()
    }

    pub fn used(&self) -> usize {
        self.telemetry.evals
    }

    pub fn remaining(&self) -> usize {
        if self.stopped_early() {
            return 0;
        }
        self.budget.saturating_sub(self.used())
    }

    pub fn exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Evaluate a batch, truncated to the remaining budget. Returns one
    /// result per *submitted* genome that fit in the budget.
    ///
    /// Every submission debits one evaluation from the budget; duplicates
    /// (within the batch or of anything evaluated before) are served from
    /// the cache without a model call. Unique genomes are evaluated in
    /// first-occurrence order, in parallel when a pool is attached.
    pub fn eval_batch(&mut self, genomes: &[Vec<u32>]) -> Vec<EvalResult> {
        let n = genomes.len().min(self.remaining());
        if n == 0 {
            return Vec::new();
        }
        let batch = &genomes[..n];

        let (slots, miss_idx, hits) = resolve_cache(&self.genome_cache, self.cache_enabled, batch);
        let misses: Vec<Vec<u32>> = miss_idx.iter().map(|&i| batch[i].clone()).collect();
        self.model_calls += misses.len();
        let miss_results = self.backend.eval(self.pool.as_ref(), &misses);
        if self.cache_enabled {
            for (g, r) in misses.iter().zip(&miss_results) {
                self.genome_cache.insert(g.clone(), *r);
            }
        }
        self.telemetry.cache_hits += hits;

        let results = assemble(slots, &miss_results);
        for (g, r) in batch.iter().zip(&results) {
            self.telemetry.record(g, r);
        }
        self.finish_batch();
        results
    }

    /// Evaluate one genome (budget permitting).
    pub fn eval_one(&mut self, genome: &[u32]) -> Option<EvalResult> {
        self.eval_batch(std::slice::from_ref(&genome.to_vec())).pop()
    }

    /// Evaluate pre-decoded designs from a *foreign* encoding (the
    /// direct-value ablation baseline). `None` designs are dead on
    /// arrival (tiling-constraint violations) but still consume budget —
    /// the evaluator would have rejected them. `record` pairs each design
    /// with the genome to log in telemetry; it also keys the cache, in a
    /// namespace separate from [`EvalContext::eval_batch`]'s since foreign
    /// encodings may reuse gene vectors with different meanings.
    pub fn eval_designs(
        &mut self,
        record: &[Vec<u32>],
        designs: &[Option<Design>],
    ) -> Vec<EvalResult> {
        assert_eq!(record.len(), designs.len());
        let n = designs.len().min(self.remaining());
        if n == 0 {
            return Vec::new();
        }

        let keys = &record[..n];
        let (slots, miss_idx, hits) = resolve_cache(&self.design_cache, self.cache_enabled, keys);
        let miss_designs: Vec<Option<Design>> =
            miss_idx.iter().map(|&i| designs[i].clone()).collect();
        self.model_calls += miss_designs.iter().filter(|d| d.is_some()).count();
        let miss_results = self.backend.eval_designs_batch(self.pool.as_ref(), miss_designs);
        if self.cache_enabled {
            for (&i, r) in miss_idx.iter().zip(&miss_results) {
                self.design_cache.insert(keys[i].clone(), *r);
            }
        }
        self.telemetry.cache_hits += hits;

        let results = assemble(slots, &miss_results);
        for (g, r) in keys.iter().zip(&results) {
            self.telemetry.record(g, r);
        }
        self.finish_batch();
        results
    }

    /// Finalize into an outcome.
    pub fn outcome(self, method: &str) -> Outcome {
        self.telemetry.into_outcome(
            method,
            &self.backend.workload().id,
            &self.backend.platform().name,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn ctx(budget: usize) -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        EvalContext::new(Backend::native(w, Platform::edge()), budget)
    }

    #[test]
    fn budget_enforced() {
        let mut c = ctx(10);
        let mut rng = Pcg64::seeded(1);
        let genomes: Vec<_> = (0..20).map(|_| c.spec.random(&mut rng)).collect();
        let r = c.eval_batch(&genomes);
        assert_eq!(r.len(), 10);
        assert!(c.exhausted());
        assert!(c.eval_batch(&genomes).is_empty());
    }

    #[test]
    fn telemetry_tracks_best() {
        let mut c = ctx(100);
        let mut rng = Pcg64::seeded(2);
        let genomes: Vec<_> = (0..50).map(|_| c.spec.random(&mut rng)).collect();
        c.eval_batch(&genomes);
        let o = c.outcome("test");
        assert_eq!(o.evals, 50);
        assert!(o.best_edp > 0.0);
        assert!(o.valid_evals <= o.evals);
        // Curve is monotone non-increasing.
        assert!(o.curve.windows(2).all(|w| w[1].1 <= w[0].1));
    }

    #[test]
    fn eval_one_consumes_budget() {
        let mut c = ctx(2);
        let mut rng = Pcg64::seeded(3);
        let g = c.spec.random(&mut rng);
        assert!(c.eval_one(&g).is_some());
        assert!(c.eval_one(&g).is_some());
        assert!(c.eval_one(&g).is_none());
    }

    #[test]
    fn parallel_matches_serial_results() {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        let mut serial = EvalContext::new(Backend::native(w.clone(), Platform::edge()), 200);
        let pool = Arc::new(ThreadPool::new(4));
        let mut par =
            EvalContext::new(Backend::native(w, Platform::edge()), 200).with_pool(Some(pool));
        assert_eq!(par.threads(), 4);
        let mut rng = Pcg64::seeded(11);
        let genomes: Vec<_> = (0..100).map(|_| serial.spec.random(&mut rng)).collect();
        assert_eq!(serial.eval_batch(&genomes), par.eval_batch(&genomes));
        assert_eq!(serial.telemetry.curve, par.telemetry.curve);
    }

    #[test]
    fn duplicates_hit_cache_but_debit_budget() {
        let mut c = ctx(50);
        let mut rng = Pcg64::seeded(5);
        let g = c.spec.random(&mut rng);
        let batch = vec![g.clone(); 8];
        let r = c.eval_batch(&batch);
        assert_eq!(r.len(), 8);
        assert_eq!(c.used(), 8, "cache hits must still debit budget");
        assert_eq!(c.model_calls(), 1, "duplicates must not re-run the model");
        assert_eq!(c.cache_hits(), 7);
        assert!(r.iter().all(|x| *x == r[0]));
        // Hits persist across batches (generations) too.
        c.eval_batch(&batch);
        assert_eq!(c.model_calls(), 1);
        assert_eq!(c.used(), 16);
    }

    #[test]
    fn cache_disabled_reruns_model() {
        let mut c = ctx(50).with_cache(false);
        let mut rng = Pcg64::seeded(6);
        let g = c.spec.random(&mut rng);
        let batch = vec![g.clone(); 4];
        c.eval_batch(&batch);
        assert_eq!(c.model_calls(), 4);
        assert_eq!(c.cache_hits(), 0);
    }

    #[test]
    fn observer_streams_progress() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        let mut c = ctx(100).with_observer(Some(Box::new(move |p: &Progress| {
            sink.lock().unwrap().push(p.clone());
            SearchControl::Continue
        })));
        let mut rng = Pcg64::seeded(7);
        let genomes: Vec<_> = (0..10).map(|_| c.spec.random(&mut rng)).collect();
        c.eval_batch(&genomes[..5]);
        c.eval_batch(&genomes[5..]);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 2);
        assert_eq!(seen[0].batches, 1);
        assert_eq!(seen[0].evals, 5);
        assert_eq!(seen[1].evals, 10);
        assert_eq!(seen[1].budget, 100);
    }

    #[test]
    fn observer_stop_halts_search() {
        let mut c = ctx(1_000).with_observer(Some(Box::new(|p: &Progress| {
            if p.evals >= 20 {
                SearchControl::Stop
            } else {
                SearchControl::Continue
            }
        })));
        let mut rng = Pcg64::seeded(8);
        loop {
            let genomes: Vec<_> = (0..10).map(|_| c.spec.random(&mut rng)).collect();
            if c.eval_batch(&genomes).is_empty() {
                break;
            }
        }
        assert!(c.stopped_early());
        assert_eq!(c.used(), 20, "stopped after the second batch");
    }

    #[test]
    fn stop_flag_cancels_externally() {
        let flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut c = ctx(100).with_stop_flag(Some(Arc::clone(&flag)));
        let mut rng = Pcg64::seeded(9);
        let genomes: Vec<_> = (0..5).map(|_| c.spec.random(&mut rng)).collect();
        assert_eq!(c.eval_batch(&genomes).len(), 5);
        flag.store(true, std::sync::atomic::Ordering::SeqCst);
        assert!(c.exhausted());
        assert!(c.eval_batch(&genomes).is_empty());
        assert!(c.stopped_early());
    }
}
