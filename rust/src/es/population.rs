//! Population primitives shared by all evolutionary searchers.

use crate::genome::{Genome, GenomeSpec};
use crate::model::EvalResult;
use crate::search::EvalContext;
use crate::util::rng::Pcg64;

/// An evaluated individual.
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Genome,
    pub result: EvalResult,
}

impl Individual {
    pub fn fitness(&self) -> f64 {
        self.result.fitness()
    }

    pub fn is_dead(&self) -> bool {
        !self.result.valid
    }
}

/// Evaluate genomes through the context and pair them up.
pub fn evaluate_all(ctx: &mut EvalContext, genomes: Vec<Genome>) -> Vec<Individual> {
    let results = ctx.eval_batch(&genomes);
    genomes
        .into_iter()
        .zip(results)
        .map(|(genome, result)| Individual { genome, result })
        .collect()
}

/// Sort by fitness descending (dead individuals last) and truncate to
/// `keep` — (μ, λ)-style truncation selection.
pub fn select_top(mut pop: Vec<Individual>, keep: usize) -> Vec<Individual> {
    pop.sort_by(|a, b| b.fitness().partial_cmp(&a.fitness()).unwrap());
    pop.truncate(keep);
    pop
}

/// Indices of the top `keep` individuals by fitness, without cloning the
/// population — the per-generation parent-selection hot path (cloning
/// every genome per generation was measurable next to the staged
/// engine's cheap evaluations). Same stable descending order as
/// [`select_top`], so trajectories are unchanged.
pub fn top_indices(pop: &[Individual], keep: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..pop.len()).collect();
    idx.sort_by(|&a, &b| pop[b].fitness().partial_cmp(&pop[a].fitness()).unwrap());
    idx.truncate(keep);
    idx
}

/// Mean EDP of the *valid* members (the Fig. 18 y-axis); `None` if all
/// dead.
pub fn mean_valid_edp(pop: &[Individual]) -> Option<f64> {
    let valid: Vec<f64> =
        pop.iter().filter(|i| i.result.valid).map(|i| i.result.edp).collect();
    if valid.is_empty() {
        None
    } else {
        Some(valid.iter().sum::<f64>() / valid.len() as f64)
    }
}

/// Latin hypercube sampling over the genome space: for each gene, the
/// population is spread across `n` equal strata of the gene's range, with
/// the stratum order shuffled independently per gene. The standard-ES
/// baseline initialization (§V ablation).
pub fn lhs_init(spec: &GenomeSpec, n: usize, rng: &mut Pcg64) -> Vec<Genome> {
    let mut pop = vec![vec![0u32; spec.len()]; n];
    for (gi, range) in spec.ranges.iter().enumerate() {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let width = range.width() as f64;
        for (stratum, &who) in order.iter().enumerate() {
            // Sample uniformly inside this individual's stratum.
            let lo = stratum as f64 / n as f64;
            let hi = (stratum + 1) as f64 / n as f64;
            let u = lo + (hi - lo) * rng.f64();
            pop[who][gi] = range.lo + ((u * width) as u32).min(range.width() - 1);
        }
    }
    pop
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    fn ctx() -> EvalContext {
        let w = Workload::spmm("t", 16, 32, 16, 0.5, 0.25);
        EvalContext::new(Backend::native(w, Platform::edge()), 10_000)
    }

    #[test]
    fn lhs_covers_strata() {
        let c = ctx();
        let mut rng = Pcg64::seeded(4);
        let n = 30;
        let pop = lhs_init(&c.spec, n, &mut rng);
        assert_eq!(pop.len(), n);
        for g in &pop {
            assert!(c.spec.in_range(g));
        }
        // For a gene with width >= n, all values should be fairly spread:
        // check the permutation gene (width 6 < 30) hits all 6 values.
        let perm_vals: std::collections::HashSet<u32> =
            pop.iter().map(|g| g[0]).collect();
        assert_eq!(perm_vals.len(), 6);
    }

    #[test]
    fn selection_sorts_and_truncates() {
        let mut c = ctx();
        let mut rng = Pcg64::seeded(5);
        let genomes: Vec<_> = (0..40).map(|_| c.spec.random(&mut rng)).collect();
        let pop = evaluate_all(&mut c, genomes);
        let top = select_top(pop.clone(), 10);
        assert_eq!(top.len(), 10);
        assert!(top.windows(2).all(|w| w[0].fitness() >= w[1].fitness()));
        // Top selection can't be worse than the population's best.
        let best_all = pop.iter().map(|i| i.fitness()).fold(0.0f64, f64::max);
        assert_eq!(top[0].fitness(), best_all);
    }

    #[test]
    fn top_indices_matches_select_top_including_ties() {
        let mut c = ctx();
        let mut rng = Pcg64::seeded(8);
        let mut genomes: Vec<_> = (0..30).map(|_| c.spec.random(&mut rng)).collect();
        // Force fitness ties: duplicate some genomes.
        genomes.extend(genomes[..10].to_vec());
        let pop = evaluate_all(&mut c, genomes);
        for keep in [1, 5, 17, 40] {
            let by_clone = select_top(pop.clone(), keep);
            let by_index = top_indices(&pop, keep);
            assert_eq!(by_clone.len(), by_index.len());
            for (a, &i) in by_clone.iter().zip(&by_index) {
                assert_eq!(a.genome, pop[i].genome, "keep={keep}");
                assert_eq!(a.result, pop[i].result, "keep={keep}");
            }
        }
    }

    #[test]
    fn mean_valid_edp_ignores_dead() {
        let mk = |edp: f64, valid: bool| Individual {
            genome: vec![],
            result: EvalResult {
                energy_pj: 1.0,
                cycles: 1.0,
                edp: if valid { edp } else { f64::INFINITY },
                valid,
            },
        };
        let pop = vec![mk(10.0, true), mk(1e9, false), mk(30.0, true)];
        assert_eq!(mean_valid_edp(&pop), Some(20.0));
        assert_eq!(mean_valid_edp(&[mk(1.0, false)]), None);
    }
}
