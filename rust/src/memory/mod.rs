//! Design memory: a persisted, ANN-indexed store of elite designs that
//! warm-starts new searches from the nearest prior scenarios.
//!
//! Every completed search that found a valid design can deposit one
//! record — scenario embedding, elite genome, outcome summary — into an
//! append-only `sparsemap.memory.v1` file ([`record`]). New searches on
//! near-duplicate scenarios then pull the `k` nearest records back out
//! through a deterministic LSH index ([`index`]) and seed a configurable
//! fraction of their initial ES population with the re-validated genomes
//! ([`store`]), so repeated traffic gets monotonically cheaper instead
//! of re-paying for knowledge a prior search already bought.
//!
//! The subsystem is **off by default**: nothing is read or written
//! unless a store path is supplied (`--memory` on the CLI,
//! `--memory-store` on the service, or a `warm_start` block on a
//! [`crate::api::SearchRequest`]), and with it unset every request,
//! report and trajectory stays byte-identical to a build without this
//! module.
//!
//! The layer is observable through the process-global [`crate::obs`]
//! registry: queries count ANN bucket probes vs exact-scan answers
//! (the ratio shows when a store outgrows the brute-force regime),
//! warm-start seed injections and store size are tracked, and
//! `sparsemap memory stats` reports a nearest-neighbour distance
//! histogram over the stored embeddings (`nn_dist`) so scenario
//! clustering — and therefore seed quality — is visible at a glance.

pub mod embed;
pub mod index;
pub mod record;
pub mod store;

pub use embed::{dist2, scenario_embedding, scenario_tag, EMBED_DIM};
pub use index::AnnIndex;
pub use record::{
    decode_file, header_bytes, salvage_file, MemRecord, Salvage, MEMORY_SCHEMA, MEMORY_VERSION,
};
pub use store::{MemoryStore, DEFAULT_CAP};
