"""AOT pipeline tests: lowering produces loadable HLO text with the
expected entry signature, and the Pallas path agrees with the ref path at
the lowered-function level (pre-artifact numerics gate).
"""

import json

import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_cost_model_hlo_text_shape_signature():
    hlo = aot.lower_cost_model()
    assert "HloModule" in hlo
    # The entry computation must consume the batch and platform operands
    # and produce a (f32[256,4]) tuple.
    assert f"f32[{model.AOT_BATCH},{ref.NUM_FEATURES}]" in hlo
    assert f"f32[{ref.NUM_PLATFORM_FEATURES}]" in hlo
    assert f"f32[{model.AOT_BATCH},4]" in hlo


def test_spmm_demo_hlo_text_shape_signature():
    hlo = aot.lower_spmm_demo()
    assert "HloModule" in hlo
    assert f"f32[{model.DEMO_M},{model.DEMO_N}]" in hlo


def test_metadata_contract():
    meta = aot.metadata()
    assert meta["schema_version"] == 1
    assert meta["batch"] == model.AOT_BATCH
    assert meta["num_features"] == ref.NUM_FEATURES
    assert meta["outputs"] == ["energy_pj", "cycles", "edp", "valid"]
    # Must serialize (this is what the Rust runtime parses).
    json.dumps(meta)


def test_pallas_and_ref_paths_agree():
    rng = np.random.default_rng(4)
    feats = rng.uniform(0.1, 100.0,
                        size=(model.AOT_BATCH, ref.NUM_FEATURES)).astype(np.float32)
    plat = rng.uniform(0.1, 10.0, size=(ref.NUM_PLATFORM_FEATURES,)).astype(np.float32)
    (a,) = model.evaluate_batch(feats, plat)
    (b,) = model.evaluate_batch_ref(feats, plat)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
