//! One tracked search job: its request, lifecycle state, buffered
//! progress events and (when suspended) its checkpoint.

use crate::api::SearchRequest;
use crate::util::json::Json;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

/// Job lifecycle. `Suspended` is the only non-terminal resting state: a
/// suspended job holds a checkpoint and goes back to `Queued` through
/// `POST /jobs/<id>/resume`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Suspended,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Suspended => "suspended",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Terminal states never change again (a suspended job can).
    pub fn terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// A tracked job. Lives in the server's state map for the life of the
/// process (and, while suspended, as a file in the checkpoint
/// directory).
pub struct Job {
    pub id: String,
    pub tenant: String,
    pub priority: i64,
    pub request: SearchRequest,
    pub state: JobState,
    pub error: Option<String>,
    /// The full serialized [`crate::api::SearchReport`] once the run
    /// finished (done, or the partial report of a suspension).
    pub report: Option<Json>,
    /// Buffered NDJSON event lines — every `/events` reader replays the
    /// buffer from the start, so late subscribers miss nothing.
    pub events: Vec<String>,
    /// No further events will arrive (the run reached a resting state).
    pub events_done: bool,
    /// Live run controls, present only while `Running`.
    pub cancel: Option<Arc<AtomicBool>>,
    pub suspend: Option<Arc<AtomicBool>>,
    /// Serialized [`crate::optimizer::Checkpoint`] of a suspended job.
    pub checkpoint: Option<Json>,
}

impl Job {
    pub fn new(id: String, tenant: String, priority: i64, request: SearchRequest) -> Job {
        Job {
            id,
            tenant,
            priority,
            request,
            state: JobState::Queued,
            error: None,
            report: None,
            events: Vec::new(),
            events_done: false,
            cancel: None,
            suspend: None,
            checkpoint: None,
        }
    }

    /// The `GET /jobs` row.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("tenant", Json::str(&self.tenant)),
            ("priority", Json::num(self.priority as f64)),
            ("method", Json::str(&self.request.method)),
            ("budget", Json::num(self.request.budget as f64)),
            ("state", Json::str(self.state.as_str())),
            ("has_checkpoint", Json::Bool(self.checkpoint.is_some())),
        ])
    }

    /// The `GET /jobs/<id>` document: summary + echoed request + the
    /// report or error once there is one.
    pub fn detail_json(&self) -> Json {
        let mut j = self.summary_json();
        if let Json::Obj(o) = &mut j {
            o.insert("request".to_string(), self.request.to_json());
            if let Some(r) = &self.report {
                o.insert("report".to_string(), r.clone());
            }
            if let Some(e) = &self.error {
                o.insert("error".to_string(), Json::str(e));
            }
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_strings_and_terminality() {
        assert_eq!(JobState::Queued.as_str(), "queued");
        assert!(!JobState::Queued.terminal());
        assert!(!JobState::Running.terminal());
        assert!(!JobState::Suspended.terminal(), "suspended jobs can resume");
        assert!(JobState::Done.terminal());
        assert!(JobState::Failed.terminal());
        assert!(JobState::Cancelled.terminal());
    }

    #[test]
    fn summary_and_detail_json_shape() {
        let mut job = Job::new(
            "job-000007".to_string(),
            "acme".to_string(),
            3,
            SearchRequest::new().workload_named("mm1").budget(500),
        );
        job.state = JobState::Suspended;
        job.checkpoint = Some(Json::Null);
        let s = job.summary_json();
        assert_eq!(s.get("id").and_then(Json::as_str), Some("job-000007"));
        assert_eq!(s.get("state").and_then(Json::as_str), Some("suspended"));
        assert_eq!(s.get("has_checkpoint").and_then(Json::as_bool), Some(true));
        let d = job.detail_json();
        assert!(d.get("request").is_some());
        assert!(d.get("report").is_none());
    }
}
