//! Sparse strategy: per-rank compression formats ([`format`]),
//! skipping/gating mechanisms ([`saf`]) and the compatibility rules
//! between sparse strategy and mapping ([`compat`]).

pub mod compat;
pub mod format;
pub mod saf;

pub use compat::Incompat;
pub use format::{
    bits_for, stack_storage, stack_storage_model, stack_words, RankFormat, NUM_RANK_FORMATS,
};
pub use saf::{control_overhead, effect, SgEffect, SgMechanism, NUM_SG_CHOICES};

/// A complete sparse strategy for one design: per-tensor format stacks
/// (aligned with the tensor's materialized ranks, outer→inner) and the
/// S/G mechanism at each of the three sites.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseStrategy {
    /// Format stack per tensor (P, Q, Z order), one entry per materialized
    /// rank of that tensor under the current mapping.
    pub formats: [Vec<RankFormat>; 3],
    /// S/G at GLB (L2), PE buffer (L3), compute (C).
    pub sg: [SgMechanism; 3],
}

impl SparseStrategy {
    /// Fully dense strategy (no compression, no S/G).
    pub fn dense(num_ranks: [usize; 3]) -> SparseStrategy {
        SparseStrategy {
            formats: [
                vec![RankFormat::Uncompressed; num_ranks[0]],
                vec![RankFormat::Uncompressed; num_ranks[1]],
                vec![RankFormat::Uncompressed; num_ranks[2]],
            ],
            sg: [SgMechanism::None; 3],
        }
    }

    /// Is tensor `t`'s stack compressed at all?
    pub fn compressed(&self, t: usize) -> bool {
        self.formats[t].iter().any(|f| f.compressing())
    }

    /// Allocation-free twin of [`SparseStrategy::check`]:
    /// `check_ok()` ⟺ `check().is_empty()` (the hot-path validity bit).
    pub fn check_ok(&self) -> bool {
        self.formats.iter().all(|s| compat::stack_ok(s))
            && compat::saf_ok(&self.sg, self.compressed(0), self.compressed(1))
    }

    /// All structural compatibility problems of this strategy.
    pub fn check(&self) -> Vec<Incompat> {
        let names: [&'static str; 3] = ["P", "Q", "Z"];
        let mut problems = Vec::new();
        for (t, name) in names.iter().enumerate() {
            problems.extend(compat::check_stack(name, &self.formats[t]));
        }
        let sites = [("GLB", self.sg[0]), ("PEBuf", self.sg[1]), ("C", self.sg[2])];
        problems.extend(compat::check_saf(&sites, self.compressed(0), self.compressed(1)));
        problems
    }

    /// Short human-readable description, e.g. `P:UOP-CP Q:B-B Z:U | GLB:Skip Q<-P`.
    pub fn describe(&self) -> String {
        let names = ["P", "Q", "Z"];
        let mut parts: Vec<String> = Vec::new();
        for (t, name) in names.iter().enumerate() {
            let stack: Vec<&str> = self.formats[t].iter().map(|f| f.short_name()).collect();
            let stack = if stack.is_empty() { "-".into() } else { stack.join("-") };
            parts.push(format!("{name}:{stack}"));
        }
        let sg: Vec<String> = ["GLB", "PEBuf", "C"]
            .iter()
            .zip(&self.sg)
            .filter(|(_, m)| **m != SgMechanism::None)
            .map(|(s, m)| format!("{s}:{}", m.name()))
            .collect();
        if sg.is_empty() {
            parts.join(" ")
        } else {
            format!("{} | {}", parts.join(" "), sg.join(" "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_strategy_valid_and_uncompressed() {
        let s = SparseStrategy::dense([2, 2, 2]);
        assert!(s.check().is_empty());
        assert!(!s.compressed(0) && !s.compressed(1) && !s.compressed(2));
    }

    #[test]
    fn check_aggregates_all_problems() {
        let mut s = SparseStrategy::dense([2, 2, 2]);
        s.formats[0] = vec![RankFormat::Bitmask, RankFormat::UncompressedOffsetPair];
        s.sg[0] = SgMechanism::SkipPfromQ; // drives on uncompressed Q
        let problems = s.check();
        assert_eq!(problems.len(), 2);
    }

    #[test]
    fn describe_readable() {
        let mut s = SparseStrategy::dense([1, 2, 1]);
        s.formats[1] = vec![RankFormat::UncompressedOffsetPair, RankFormat::CoordinatePayload];
        s.sg[2] = SgMechanism::GateBoth;
        let d = s.describe();
        assert!(d.contains("Q:UOP-CP"), "{d}");
        assert!(d.contains("C:Gate P<->Q"), "{d}");
    }
}
