//! E4/E5 / Fig. 17 — (a) SparseMap vs classical optimizers on the pruned
//! VGG16 conv layers (cloud platform, shared budget); (b) percentage of
//! valid points explored per platform, averaged over the conv layers.

use super::{write_csv, ExpConfig};
use crate::api::{run_batch, SearchRequest};
use crate::arch::Platform;
use crate::search::Outcome;
use crate::util::stats::geomean;
use crate::util::table::{sci, Table};

/// The Fig. 17a method set.
pub const FIG17_METHODS: &[&str] = &["sparsemap", "pso", "mcts", "tbpsa", "ppo", "dqn"];

/// Run every (method, conv-layer) arm on the given platform through the
/// batch API. Arms evaluate serially inside (the parallelism is across
/// arms) and always on the native backend — PJRT clients are not shared
/// across threads; the two backends are cross-validated.
pub fn run_matrix(cfg: &ExpConfig, platform: &Platform, layers: &[&str]) -> Vec<Outcome> {
    let requests: Vec<SearchRequest> = FIG17_METHODS
        .iter()
        .flat_map(|m| {
            layers.iter().map(move |l| {
                SearchRequest::new()
                    .workload_named(l)
                    .platform(platform.clone())
                    .method(m)
                    .budget(cfg.budget)
                    .seed(cfg.seed)
            })
        })
        .collect();
    let reports = run_batch(requests, cfg.threads.max(1)).expect("fig17 arms validate");
    reports.into_iter().map(|r| r.into_outcome()).collect()
}

/// Fig. 17a: EDP per conv layer per method on cloud.
pub fn run_a(cfg: &ExpConfig) -> anyhow::Result<String> {
    let layers: Vec<&str> =
        (1..=13).map(|i| Box::leak(format!("conv{i}").into_boxed_str()) as &str).collect();
    let outcomes = run_matrix(cfg, &Platform::cloud(), &layers);

    let mut table = Table::new(
        &["layer", "sparsemap", "pso", "mcts", "tbpsa", "ppo", "dqn", "best"],
    );
    let mut csv = String::from("layer,method,best_edp,valid_ratio\n");
    for layer in &layers {
        let mut cells = vec![layer.to_string()];
        let mut best = ("", f64::INFINITY);
        for method in FIG17_METHODS {
            let o = outcomes
                .iter()
                .find(|o| &o.workload == layer && &o.method == method)
                .expect("outcome");
            cells.push(if o.found_valid() { sci(o.best_edp) } else { "-".into() });
            if o.best_edp < best.1 {
                best = (method, o.best_edp);
            }
            csv.push_str(&format!(
                "{layer},{method},{},{:.4}\n",
                if o.found_valid() { format!("{:.6e}", o.best_edp) } else { String::new() },
                o.valid_ratio()
            ));
        }
        cells.push(best.0.to_string());
        table.row(cells);
    }
    write_csv(&cfg.out_dir, "fig17a.csv", &csv)?;

    // Geomean improvement of SparseMap over each baseline.
    let mut summary = String::new();
    for method in &FIG17_METHODS[1..] {
        let ratios: Vec<f64> = layers
            .iter()
            .filter_map(|layer| {
                let ours = outcomes
                    .iter()
                    .find(|o| &o.workload == layer && o.method == "sparsemap")?;
                let theirs = outcomes
                    .iter()
                    .find(|o| &o.workload == layer && &o.method == method)?;
                if ours.found_valid() && theirs.found_valid() {
                    Some(theirs.best_edp / ours.best_edp)
                } else if ours.found_valid() {
                    Some(1e6) // baseline found nothing valid at all
                } else {
                    None
                }
            })
            .collect();
        summary.push_str(&format!(
            "  vs {:8}: geomean EDP reduction {:.1}x\n",
            method,
            geomean(&ratios)
        ));
    }
    Ok(format!(
        "Fig. 17a — pruned VGG16 @ cloud, budget {} per arm\n{}\nSparseMap improvement:\n{}",
        cfg.budget,
        table.render(),
        summary
    ))
}

/// Fig. 17b: valid-point percentage per platform (avg over conv layers).
pub fn run_b(cfg: &ExpConfig) -> anyhow::Result<String> {
    // A subset of layers keeps the default run affordable; the full list
    // is used when budget <= 5000 is overridden upward.
    let layers = ["conv2", "conv4", "conv7", "conv11"];
    let mut table = Table::new(&["platform", "sparsemap", "pso", "mcts", "tbpsa", "ppo", "dqn"]);
    let mut csv = String::from("platform,method,valid_ratio\n");
    for plat in Platform::all() {
        let outcomes = run_matrix(cfg, &plat, &layers);
        let mut cells = vec![plat.name.clone()];
        for method in FIG17_METHODS {
            let ratios: Vec<f64> = outcomes
                .iter()
                .filter(|o| &o.method == method)
                .map(|o| o.valid_ratio())
                .collect();
            let avg = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
            cells.push(format!("{:.1}%", 100.0 * avg));
            csv.push_str(&format!("{},{},{:.4}\n", plat.name, method, avg));
        }
        table.row(cells);
    }
    write_csv(&cfg.out_dir, "fig17b.csv", &csv)?;
    Ok(format!(
        "Fig. 17b — valid points explored (avg over {} conv layers, budget {})\n{}",
        layers.len(),
        cfg.budget,
        table.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            budget: 300,
            threads: 4,
            out_dir: std::env::temp_dir().join("sparsemap_fig17"),
            ..Default::default()
        }
    }

    #[test]
    fn matrix_runs_all_arms() {
        let cfg = tiny_cfg();
        let outcomes = run_matrix(&cfg, &Platform::cloud(), &["conv11"]);
        assert_eq!(outcomes.len(), FIG17_METHODS.len());
        for o in &outcomes {
            assert!(o.evals <= cfg.budget);
        }
    }

    #[test]
    fn sparsemap_explores_more_valid_points_than_weakest_baseline() {
        let cfg = ExpConfig { budget: 800, threads: 4, ..tiny_cfg() };
        let outcomes = run_matrix(&cfg, &Platform::cloud(), &["conv11"]);
        let get = |m: &str| outcomes.iter().find(|o| o.method == m).unwrap().valid_ratio();
        let ours = get("sparsemap");
        let weakest = FIG17_METHODS[1..].iter().map(|m| get(m)).fold(f64::INFINITY, f64::min);
        assert!(ours >= weakest, "sparsemap {ours} < weakest baseline {weakest}");
    }
}
