//! Batched fitness evaluation through the AOT cost-model executable, and
//! the gated-SpMM demo runner.

use super::client::Runtime;
use crate::arch::Platform;
use crate::genome::{decode, GenomeSpec};
use crate::model::{extract, EvalResult, NUM_FEATURES};
use crate::workload::Workload;
use anyhow::{anyhow, Result};

/// Evaluates whole populations per PJRT call. One instance per
/// (workload, platform) search arm; the compiled executable is shared
/// state inside the `xla` crate and cheap to clone handles of.
pub struct BatchEvaluator {
    exe: xla::PjRtLoadedExecutable,
    pub workload: Workload,
    pub platform: Platform,
    pub spec: GenomeSpec,
    batch: usize,
    plat_row: Vec<f32>,
}

impl BatchEvaluator {
    pub fn new(rt: &Runtime, workload: Workload, platform: Platform) -> Result<BatchEvaluator> {
        let exe = rt.compile(&rt.meta.cost_model_file)?;
        let spec = GenomeSpec::for_workload(&workload);
        let plat_row = platform.to_feature_vector();
        Ok(BatchEvaluator { exe, workload, platform, spec, batch: rt.meta.batch, plat_row })
    }

    /// The static batch size of the executable (padding granularity).
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Evaluate a slice of genomes. Internally pads to the executable's
    /// static batch; results are returned in input order.
    pub fn eval_genomes(&self, genomes: &[Vec<u32>]) -> Result<Vec<EvalResult>> {
        let mut out = Vec::with_capacity(genomes.len());
        for chunk in genomes.chunks(self.batch) {
            out.extend(self.eval_chunk(chunk)?);
        }
        Ok(out)
    }

    /// Evaluate pre-decoded designs (used by foreign encodings such as
    /// the direct-value ablation baseline).
    pub fn eval_designs(&self, designs: &[crate::genome::Design]) -> Result<Vec<EvalResult>> {
        let mut out = Vec::with_capacity(designs.len());
        for chunk in designs.chunks(self.batch) {
            let rows: Vec<crate::model::Features> = chunk
                .iter()
                .map(|d| extract(d, &self.workload, &self.platform))
                .collect();
            out.extend(self.execute_rows(&rows)?);
        }
        Ok(out)
    }

    fn eval_chunk(&self, chunk: &[Vec<u32>]) -> Result<Vec<EvalResult>> {
        debug_assert!(chunk.len() <= self.batch);
        // Extract features (combinatorial analysis on the Rust side).
        let rows: Vec<crate::model::Features> = chunk
            .iter()
            .map(|genome| {
                let design = decode(&self.spec, &self.workload, genome);
                extract(&design, &self.workload, &self.platform)
            })
            .collect();
        self.execute_rows(&rows)
    }

    fn execute_rows(&self, rows: &[crate::model::Features]) -> Result<Vec<EvalResult>> {
        debug_assert!(rows.len() <= self.batch);
        let mut flat = vec![0f32; self.batch * NUM_FEATURES];
        for (i, feats) in rows.iter().enumerate() {
            for (j, &v) in feats.iter().enumerate() {
                flat[i * NUM_FEATURES + j] = v as f32;
            }
        }
        let feats_lit = xla::Literal::vec1(&flat)
            .reshape(&[self.batch as i64, NUM_FEATURES as i64])
            .map_err(|e| anyhow!("reshape features: {e:?}"))?;
        let plat_lit = xla::Literal::vec1(&self.plat_row);

        let result = self
            .exe
            .execute::<xla::Literal>(&[feats_lit, plat_lit])
            .map_err(|e| anyhow!("execute cost model: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let table = result
            .to_tuple1()
            .map_err(|e| anyhow!("unwrap tuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("read result: {e:?}"))?;
        debug_assert_eq!(table.len(), self.batch * 4);

        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| {
                let row = &table[i * 4..i * 4 + 4];
                let valid = row[3] > 0.5;
                EvalResult {
                    energy_pj: row[0] as f64,
                    cycles: row[1] as f64,
                    edp: if valid { row[2] as f64 } else { f64::INFINITY },
                    valid,
                }
            })
            .collect())
    }
}

/// The instantiated-design demo: run the gated-SpMM artifact on concrete
/// tiles (Fig. 14's hardware behaviour, executed through PJRT).
pub struct SpmmDemo {
    exe: xla::PjRtLoadedExecutable,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl SpmmDemo {
    pub fn new(rt: &Runtime) -> Result<SpmmDemo> {
        let exe = rt.compile(&rt.meta.spmm_demo_file)?;
        let (m, k, n) = rt.meta.demo_shape;
        Ok(SpmmDemo { exe, m, k, n })
    }

    /// Execute Z = (P⊙maskP)(Q⊙maskQ); returns (z, effectual_macs).
    pub fn run(
        &self,
        p: &[f32],
        q: &[f32],
        pmask: &[f32],
        qmask: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        let (m, k, n) = (self.m as i64, self.k as i64, self.n as i64);
        anyhow::ensure!(p.len() == (m * k) as usize, "P size mismatch");
        anyhow::ensure!(q.len() == (k * n) as usize, "Q size mismatch");
        let args = [
            xla::Literal::vec1(p).reshape(&[m, k]).map_err(|e| anyhow!("{e:?}"))?,
            xla::Literal::vec1(q).reshape(&[k, n]).map_err(|e| anyhow!("{e:?}"))?,
            xla::Literal::vec1(pmask).reshape(&[m, k]).map_err(|e| anyhow!("{e:?}"))?,
            xla::Literal::vec1(qmask).reshape(&[k, n]).map_err(|e| anyhow!("{e:?}"))?,
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute spmm demo: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let (z_lit, eff_lit) =
            result.to_tuple2().map_err(|e| anyhow!("unwrap tuple2: {e:?}"))?;
        let z = z_lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let eff = eff_lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((z, eff[0] as f64))
    }
}
