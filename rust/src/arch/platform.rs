//! Hardware platforms (Table II): edge, mobile, cloud.

use super::energy::EnergyTable;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Platform resource constraints + derived constants.
///
/// Word width is 16 bits throughout (activation/weight precision of the
/// DSTC-class accelerators the paper anchors on).
#[derive(Clone, Debug, PartialEq)]
pub struct Platform {
    pub name: String,
    /// PE array extent (total PEs = `pe_rows * pe_cols`).
    pub pe_rows: u64,
    pub pe_cols: u64,
    /// MAC units per PE.
    pub macs_per_pe: u64,
    /// PE-local buffer bytes.
    pub pe_buf_bytes: u64,
    /// Global buffer bytes.
    pub glb_bytes: u64,
    /// DRAM bandwidth, bytes/second.
    pub dram_bw_bytes_per_s: f64,
    /// Clock, Hz.
    pub clock_hz: f64,
    /// On-chip GLB↔PE aggregate bandwidth, words/cycle.
    pub glb_bw_words_per_cycle: f64,
    /// PE-buffer→MAC aggregate bandwidth per PE, words/cycle.
    pub pe_bw_words_per_cycle: f64,
    pub energy: EnergyTable,
}

/// Bytes per data word (16-bit).
pub const WORD_BYTES: u64 = 2;
/// Bits per data word.
pub const WORD_BITS: u64 = 16;

impl Platform {
    pub fn total_pes(&self) -> u64 {
        self.pe_rows * self.pe_cols
    }

    pub fn total_macs(&self) -> u64 {
        self.total_pes() * self.macs_per_pe
    }

    /// GLB capacity in words.
    pub fn glb_words(&self) -> f64 {
        (self.glb_bytes / WORD_BYTES) as f64
    }

    /// PE buffer capacity in words.
    pub fn pe_buf_words(&self) -> f64 {
        (self.pe_buf_bytes / WORD_BYTES) as f64
    }

    /// DRAM bandwidth in words per clock cycle.
    pub fn dram_words_per_cycle(&self) -> f64 {
        self.dram_bw_bytes_per_s / WORD_BYTES as f64 / self.clock_hz
    }

    /// Table II: Eyeriss-class edge platform.
    /// 16×16 PEs, 1 MAC/PE, 1 KB PE buffer, 128 KB GLB, 16 MB/s DRAM.
    pub fn edge() -> Platform {
        Platform {
            name: "edge".into(),
            pe_rows: 16,
            pe_cols: 16,
            macs_per_pe: 1,
            pe_buf_bytes: 1 << 10,
            glb_bytes: 128 << 10,
            dram_bw_bytes_per_s: 16e6,
            clock_hz: 200e6, // embedded-class clock
            glb_bw_words_per_cycle: 32.0,
            pe_bw_words_per_cycle: 2.0,
            energy: EnergyTable::for_capacities(128 << 10, 1 << 10),
        }
    }

    /// Table II: mobile platform. 16×16 PEs, 64 MACs/PE, 32 KB PE buffer,
    /// 16 MB GLB, 32 GB/s DRAM.
    pub fn mobile() -> Platform {
        Platform {
            name: "mobile".into(),
            pe_rows: 16,
            pe_cols: 16,
            macs_per_pe: 64,
            pe_buf_bytes: 32 << 10,
            glb_bytes: 16 << 20,
            dram_bw_bytes_per_s: 32e9,
            clock_hz: 800e6,
            glb_bw_words_per_cycle: 128.0,
            pe_bw_words_per_cycle: 64.0,
            energy: EnergyTable::for_capacities(16 << 20, 32 << 10),
        }
    }

    /// Table II: cloud-TPU-class platform. 32×32 PEs, 64 MACs/PE, 128 KB
    /// PE buffer, 64 MB GLB, 128 GB/s DRAM.
    pub fn cloud() -> Platform {
        Platform {
            name: "cloud".into(),
            pe_rows: 32,
            pe_cols: 32,
            macs_per_pe: 64,
            pe_buf_bytes: 128 << 10,
            glb_bytes: 64 << 20,
            dram_bw_bytes_per_s: 128e9,
            clock_hz: 1e9,
            glb_bw_words_per_cycle: 512.0,
            pe_bw_words_per_cycle: 64.0,
            energy: EnergyTable::for_capacities(64 << 20, 128 << 10),
        }
    }

    /// Validated constructor for custom (non-Table-II) platforms: any PE
    /// array geometry with the energy table derived from the buffer
    /// capacities, exactly like the built-in platforms.
    #[allow(clippy::too_many_arguments)]
    pub fn custom(
        name: &str,
        pe_rows: u64,
        pe_cols: u64,
        macs_per_pe: u64,
        pe_buf_bytes: u64,
        glb_bytes: u64,
        dram_bw_bytes_per_s: f64,
        clock_hz: f64,
        glb_bw_words_per_cycle: f64,
        pe_bw_words_per_cycle: f64,
    ) -> Result<Platform> {
        let p = Platform {
            name: name.to_string(),
            pe_rows,
            pe_cols,
            macs_per_pe,
            pe_buf_bytes,
            glb_bytes,
            dram_bw_bytes_per_s,
            clock_hz,
            glb_bw_words_per_cycle,
            pe_bw_words_per_cycle,
            energy: EnergyTable::for_capacities(glb_bytes, pe_buf_bytes),
        };
        p.validate()?;
        Ok(p)
    }

    /// Check the resource invariants the cost model relies on.
    pub fn validate(&self) -> Result<()> {
        use anyhow::ensure;
        ensure!(!self.name.is_empty(), "platform name must not be empty");
        ensure!(
            self.pe_rows >= 1 && self.pe_cols >= 1,
            "platform '{}' PE grid {}x{} must be positive in both extents",
            self.name,
            self.pe_rows,
            self.pe_cols
        );
        ensure!(self.macs_per_pe >= 1, "platform '{}' needs at least 1 MAC per PE", self.name);
        ensure!(
            self.pe_buf_bytes >= WORD_BYTES && self.glb_bytes >= WORD_BYTES,
            "platform '{}' buffers must hold at least one {}-byte word",
            self.name,
            WORD_BYTES
        );
        ensure!(
            self.dram_bw_bytes_per_s > 0.0 && self.dram_bw_bytes_per_s.is_finite(),
            "platform '{}' DRAM bandwidth must be positive",
            self.name
        );
        ensure!(
            self.clock_hz > 0.0 && self.clock_hz.is_finite(),
            "platform '{}' clock must be positive",
            self.name
        );
        ensure!(
            self.glb_bw_words_per_cycle > 0.0 && self.pe_bw_words_per_cycle > 0.0,
            "platform '{}' on-chip bandwidths must be positive",
            self.name
        );
        Ok(())
    }

    /// Parse a JSON platform spec: either a bare name (`"cloud"`) or a
    /// full custom description. Convenience unit fields are accepted
    /// alongside the raw ones (`pe_buf_kib`/`glb_kib` for bytes,
    /// `dram_gbps` for bytes/s, `clock_ghz` for Hz).
    pub fn from_spec(j: &Json) -> Result<Platform> {
        if let Some(name) = j.as_str() {
            return Platform::by_name(name);
        }
        anyhow::ensure!(j.as_obj().is_some(), "platform spec must be a name or a JSON object");
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("platform spec is missing 'name'"))?;
        let u64_field = |key: &str| -> Result<u64> {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("platform spec field '{key}' must be a whole number"))
        };
        let f64_field = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("platform spec field '{key}' must be a number"))
        };
        let bytes_field = |raw: &str, kib: &str| -> Result<u64> {
            if j.get(raw).is_some() {
                u64_field(raw)
            } else if j.get(kib).is_some() {
                Ok(u64_field(kib)? << 10)
            } else {
                Err(anyhow!("platform spec needs '{raw}' (bytes) or '{kib}' (KiB)"))
            }
        };
        let dram_bw = if j.get("dram_bw_bytes_per_s").is_some() {
            f64_field("dram_bw_bytes_per_s")?
        } else {
            f64_field("dram_gbps")? * 1e9
        };
        let clock = if j.get("clock_hz").is_some() {
            f64_field("clock_hz")?
        } else {
            f64_field("clock_ghz")? * 1e9
        };
        Platform::custom(
            name,
            u64_field("pe_rows")?,
            u64_field("pe_cols")?,
            u64_field("macs_per_pe")?,
            bytes_field("pe_buf_bytes", "pe_buf_kib")?,
            bytes_field("glb_bytes", "glb_kib")?,
            dram_bw,
            clock,
            f64_field("glb_bw_words_per_cycle")?,
            f64_field("pe_bw_words_per_cycle")?,
        )
    }

    /// Emit the full JSON spec (raw units). Inverse of [`Self::from_spec`]:
    /// parsing the result reproduces the platform exactly.
    pub fn to_spec_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("pe_rows", Json::num(self.pe_rows as f64)),
            ("pe_cols", Json::num(self.pe_cols as f64)),
            ("macs_per_pe", Json::num(self.macs_per_pe as f64)),
            ("pe_buf_bytes", Json::num(self.pe_buf_bytes as f64)),
            ("glb_bytes", Json::num(self.glb_bytes as f64)),
            ("dram_bw_bytes_per_s", Json::num(self.dram_bw_bytes_per_s)),
            ("clock_hz", Json::num(self.clock_hz)),
            ("glb_bw_words_per_cycle", Json::num(self.glb_bw_words_per_cycle)),
            ("pe_bw_words_per_cycle", Json::num(self.pe_bw_words_per_cycle)),
        ])
    }

    pub fn by_name(name: &str) -> Result<Platform> {
        match name {
            "edge" => Ok(Platform::edge()),
            "mobile" => Ok(Platform::mobile()),
            "cloud" => Ok(Platform::cloud()),
            other => Err(anyhow!("unknown platform '{other}' (edge|mobile|cloud)")),
        }
    }

    pub fn all() -> Vec<Platform> {
        vec![Platform::edge(), Platform::mobile(), Platform::cloud()]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("pes", Json::num(self.total_pes() as f64)),
            ("macs_per_pe", Json::num(self.macs_per_pe as f64)),
            ("pe_buf_bytes", Json::num(self.pe_buf_bytes as f64)),
            ("glb_bytes", Json::num(self.glb_bytes as f64)),
            ("dram_bw", Json::num(self.dram_bw_bytes_per_s)),
        ])
    }

    /// The 16-float platform vector consumed by the AOT fitness evaluator
    /// (see `python/compile/model.py`, PLATFORM_VECTOR layout).
    pub fn to_feature_vector(&self) -> Vec<f32> {
        vec![
            self.energy.dram as f32,
            self.energy.glb as f32,
            self.energy.pe_buf as f32,
            self.energy.reg as f32,
            self.energy.mac as f32,
            self.energy.noc as f32,
            self.energy.metadata as f32,
            self.dram_words_per_cycle() as f32,
            self.glb_bw_words_per_cycle as f32,
            self.pe_bw_words_per_cycle as f32,
            self.glb_words() as f32,
            self.pe_buf_words() as f32,
            self.total_pes() as f32,
            self.macs_per_pe as f32,
            self.clock_hz as f32,
            0.0, // reserved
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_resources() {
        let e = Platform::edge();
        assert_eq!(e.total_pes(), 256);
        assert_eq!(e.total_macs(), 256);
        assert_eq!(e.glb_bytes, 128 * 1024);
        let m = Platform::mobile();
        assert_eq!(m.total_macs(), 256 * 64);
        let c = Platform::cloud();
        assert_eq!(c.total_pes(), 1024);
        assert_eq!(c.total_macs(), 1024 * 64);
        assert_eq!(c.glb_bytes, 64 << 20);
    }

    #[test]
    fn bandwidth_ordering() {
        let e = Platform::edge();
        let c = Platform::cloud();
        // Edge DRAM is profoundly bandwidth-starved (16 MB/s) vs cloud.
        assert!(e.dram_words_per_cycle() < 0.1);
        assert!(c.dram_words_per_cycle() > 10.0);
    }

    #[test]
    fn by_name_roundtrip() {
        for p in Platform::all() {
            assert_eq!(Platform::by_name(&p.name).unwrap(), p);
        }
        assert!(Platform::by_name("laptop").is_err());
    }

    #[test]
    fn feature_vector_len() {
        assert_eq!(Platform::edge().to_feature_vector().len(), 16);
    }

    #[test]
    fn custom_platform_validates() {
        let p = Platform::custom("pico", 8, 8, 4, 2 << 10, 256 << 10, 8e9, 5e8, 16.0, 4.0)
            .unwrap();
        assert_eq!(p.total_pes(), 64);
        assert_eq!(p.energy, EnergyTable::for_capacities(256 << 10, 2 << 10));
        // Non-positive PE grid, zero-capacity buffers and dead clocks are
        // rejected.
        assert!(Platform::custom("bad", 0, 8, 4, 2 << 10, 256 << 10, 8e9, 5e8, 16.0, 4.0)
            .is_err());
        assert!(Platform::custom("bad", 8, 8, 0, 2 << 10, 256 << 10, 8e9, 5e8, 16.0, 4.0)
            .is_err());
        assert!(Platform::custom("bad", 8, 8, 4, 0, 256 << 10, 8e9, 5e8, 16.0, 4.0).is_err());
        assert!(Platform::custom("bad", 8, 8, 4, 2 << 10, 256 << 10, 0.0, 5e8, 16.0, 4.0)
            .is_err());
    }

    #[test]
    fn spec_json_round_trips() {
        use crate::util::json::Json;
        for p in Platform::all() {
            let j = p.to_spec_json();
            let p2 = Platform::from_spec(&Json::parse(&j.dumps()).unwrap()).unwrap();
            assert_eq!(p, p2);
        }
        // Bare names resolve through by_name.
        assert_eq!(Platform::from_spec(&Json::str("edge")).unwrap(), Platform::edge());
        // Convenience units.
        let src = r#"{"name": "tiny", "pe_rows": 4, "pe_cols": 4, "macs_per_pe": 1,
                      "pe_buf_kib": 1, "glb_kib": 64, "dram_gbps": 1, "clock_ghz": 0.2,
                      "glb_bw_words_per_cycle": 8, "pe_bw_words_per_cycle": 2}"#;
        let p = Platform::from_spec(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(p.pe_buf_bytes, 1 << 10);
        assert_eq!(p.glb_bytes, 64 << 10);
        assert!((p.dram_bw_bytes_per_s - 1e9).abs() < 1.0);
    }
}
