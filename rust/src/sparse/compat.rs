//! Sparse-strategy ⇄ mapping compatibility rules.
//!
//! §III.B-2 of the paper: a large share of the joint design space is
//! *invalid* — either resources are over-subscribed or the mapping and
//! sparse strategy are mutually inconsistent. These rules define the
//! inconsistency half (capacity/fanout checks live in `model::validity`):
//!
//! 1. **Skipping needs metadata.** A skip mechanism driven by operand X
//!    requires X to have at least one compressing rank at (or above) the
//!    site — otherwise there is no nonzero-location metadata to jump with.
//! 2. **UOP needs a compressed child.** `UOP` encodes segment offsets
//!    *into* a compressed child rank; it is invalid at the innermost rank
//!    of a stack and invalid directly above an uncompressed rank (there
//!    are no variable-length segments to offset into). Plain uncompressed
//!    ranks under Bitmask/RLE/CP are fine — that is ordinary block-sparse
//!    storage (dense payload blocks under sparse outer coordinates).

use super::format::RankFormat;
use super::saf::SgMechanism;

/// Why a strategy/mapping combination is invalid. Used for diagnostics
/// and for Fig. 7-style invalid-point analysis.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Incompat {
    /// Skip mechanism at `site` drives on a tensor with no compressed rank.
    SkipNeedsCompressedDriver { site: &'static str, tensor: &'static str },
    /// UOP at the innermost rank of the tensor's stack.
    UopAtLeaf { tensor: &'static str },
    /// UOP directly above an uncompressed rank (no segments to index).
    UopNeedsCompressedChild { tensor: &'static str },
}

impl std::fmt::Display for Incompat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Incompat::SkipNeedsCompressedDriver { site, tensor } => {
                write!(f, "skip at {site} drives on uncompressed tensor {tensor}")
            }
            Incompat::UopAtLeaf { tensor } => {
                write!(f, "UOP at innermost rank of {tensor}")
            }
            Incompat::UopNeedsCompressedChild { tensor } => {
                write!(f, "UOP above an uncompressed rank in {tensor}")
            }
        }
    }
}

/// Check a per-tensor format stack (outer→inner ranks) for structural
/// validity (rule 2 in both halves).
pub fn check_stack(tensor: &'static str, stack: &[RankFormat]) -> Vec<Incompat> {
    let mut problems = Vec::new();
    for (i, f) in stack.iter().enumerate() {
        if *f != RankFormat::UncompressedOffsetPair {
            continue;
        }
        match stack.get(i + 1) {
            // UOP at the innermost rank: nothing to offset into.
            None => {
                problems.push(Incompat::UopAtLeaf { tensor });
                break;
            }
            // UOP above a dense rank: segments are fixed-length, the
            // offset array is meaningless (and the hardware indexer
            // expects variable-length children).
            Some(child) if !child.compressing() => {
                problems.push(Incompat::UopNeedsCompressedChild { tensor });
                break;
            }
            Some(_) => {}
        }
    }
    problems
}

/// Allocation-free twin of [`check_stack`]: `stack_ok(s)` ⟺
/// `check_stack(_, s).is_empty()` (enforced by tests exhaustively over
/// all 5-format stacks). The staged evaluation engine calls this on the
/// hot path, where building a diagnostics `Vec` per genome is waste.
pub fn stack_ok(stack: &[RankFormat]) -> bool {
    for (i, f) in stack.iter().enumerate() {
        if *f != RankFormat::UncompressedOffsetPair {
            continue;
        }
        match stack.get(i + 1) {
            None => return false,
            Some(child) if !child.compressing() => return false,
            Some(_) => {}
        }
    }
    true
}

/// Check S/G mechanisms against the P/Q format stacks (rule 1). `sites`
/// pairs a site name with its mechanism.
pub fn check_saf(
    sites: &[(&'static str, SgMechanism)],
    p_compressed: bool,
    q_compressed: bool,
) -> Vec<Incompat> {
    let mut problems = Vec::new();
    for &(site, m) in sites {
        if !m.is_skip() {
            continue;
        }
        let (needs_p, needs_q) = m.drivers();
        if needs_p && !p_compressed {
            problems.push(Incompat::SkipNeedsCompressedDriver { site, tensor: "P" });
        }
        if needs_q && !q_compressed {
            problems.push(Incompat::SkipNeedsCompressedDriver { site, tensor: "Q" });
        }
    }
    problems
}

/// Allocation-free twin of [`check_saf`]: `saf_ok(m, p, q)` ⟺
/// `check_saf(sites, p, q).is_empty()` for the same mechanisms
/// (enforced exhaustively by tests).
pub fn saf_ok(mechs: &[SgMechanism], p_compressed: bool, q_compressed: bool) -> bool {
    mechs.iter().all(|&m| {
        if !m.is_skip() {
            return true;
        }
        let (needs_p, needs_q) = m.drivers();
        (!needs_p || p_compressed) && (!needs_q || q_compressed)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use RankFormat::*;

    #[test]
    fn csr_is_valid() {
        assert!(check_stack("P", &[UncompressedOffsetPair, CoordinatePayload]).is_empty());
    }

    #[test]
    fn uop_leaf_invalid() {
        let p = check_stack("P", &[Bitmask, UncompressedOffsetPair]);
        assert_eq!(p, vec![Incompat::UopAtLeaf { tensor: "P" }]);
        // UOP alone is also a leaf.
        assert!(!check_stack("Q", &[UncompressedOffsetPair]).is_empty());
    }

    #[test]
    fn uop_over_dense_invalid_but_blocksparse_fine() {
        let p = check_stack("P", &[UncompressedOffsetPair, Uncompressed]);
        assert!(p.contains(&Incompat::UopNeedsCompressedChild { tensor: "P" }));
        // Block-sparse: compressed outer rank over dense payload — valid.
        assert!(check_stack("P", &[Bitmask, Uncompressed]).is_empty());
        assert!(check_stack("P", &[Uncompressed, Bitmask]).is_empty());
    }

    #[test]
    fn fully_uncompressed_valid() {
        assert!(check_stack("Z", &[Uncompressed, Uncompressed]).is_empty());
    }

    #[test]
    fn skip_requires_driver_metadata() {
        let sites = [("GLB", SgMechanism::SkipPfromQ)];
        // Q uncompressed -> invalid.
        let p = check_saf(&sites, true, false);
        assert_eq!(p.len(), 1);
        // Q compressed -> fine.
        assert!(check_saf(&sites, false, true).is_empty());
    }

    #[test]
    fn gate_never_needs_metadata() {
        let sites = [("C", SgMechanism::GateBoth)];
        assert!(check_saf(&sites, false, false).is_empty());
    }

    #[test]
    fn double_sided_skip_needs_both() {
        let sites = [("PEBuf", SgMechanism::SkipBoth)];
        assert_eq!(check_saf(&sites, false, false).len(), 2);
        assert_eq!(check_saf(&sites, true, false).len(), 1);
        assert!(check_saf(&sites, true, true).is_empty());
    }

    #[test]
    fn stack_ok_matches_check_stack_exhaustively() {
        // All stacks of length 0..=5 over the 5 formats (5^5 = 3125 at
        // the longest): the boolean twin must agree with the diagnostic
        // path everywhere — the staged engine's validity bit depends on it.
        let fmts: Vec<RankFormat> = (0..5).map(RankFormat::from_gene).collect();
        let mut stack = Vec::new();
        fn rec(fmts: &[RankFormat], stack: &mut Vec<RankFormat>, depth: usize) {
            assert_eq!(
                stack_ok(stack),
                check_stack("T", stack).is_empty(),
                "divergence on {stack:?}"
            );
            if depth == 0 {
                return;
            }
            for &f in fmts {
                stack.push(f);
                rec(fmts, stack, depth - 1);
                stack.pop();
            }
        }
        rec(&fmts, &mut stack, 5);
    }

    #[test]
    fn saf_ok_matches_check_saf_exhaustively() {
        for g0 in 0..7u32 {
            for g1 in 0..7u32 {
                for g2 in 0..7u32 {
                    let mechs = [
                        SgMechanism::from_gene(g0),
                        SgMechanism::from_gene(g1),
                        SgMechanism::from_gene(g2),
                    ];
                    let sites =
                        [("GLB", mechs[0]), ("PEBuf", mechs[1]), ("C", mechs[2])];
                    for p in [false, true] {
                        for q in [false, true] {
                            assert_eq!(
                                saf_ok(&mechs, p, q),
                                check_saf(&sites, p, q).is_empty(),
                                "divergence on {mechs:?} p={p} q={q}"
                            );
                        }
                    }
                }
            }
        }
    }
}
