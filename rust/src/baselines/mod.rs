//! Prior-work and classical-optimizer baselines (§III.C, §V): random /
//! Sparseloop-Mapper-like / SAGE-like sampling arms, PSO, MCTS, TBPSA,
//! PPO, DQN, and the direct-encoding standard ES ablation.

pub mod common;
pub mod direct;
pub mod es_direct;
pub mod mcts;
pub mod nn;
pub mod pso;
pub mod rl;
pub mod samplers;
pub mod space;
pub mod tbpsa;

pub use direct::DirectSpec;
pub use es_direct::es_direct;
pub use mcts::mcts;
pub use pso::pso;
pub use rl::{dqn, ppo};
pub use samplers::{pure_random, sage_like, sparseloop_mapper};
pub use tbpsa::tbpsa;

use crate::es::{run_sparsemap, EsConfig, EsVariant};
use crate::search::{EvalContext, Outcome};

/// All method names runnable through [`run_method`].
pub const ALL_METHODS: &[&str] = &[
    "sparsemap",
    "es-pfce",
    "es-direct",
    "random",
    "sparseloop",
    "sage-like",
    "pso",
    "mcts",
    "tbpsa",
    "ppo",
    "dqn",
];

/// Dispatch a search method by name — the internal engine behind
/// [`crate::api::SearchSession::run`]. Downstream users should go
/// through [`crate::api::SearchRequest`]; this stays public for drivers
/// that assemble their own [`EvalContext`].
///
/// Every method evaluates through the [`EvalContext`] it is handed, so
/// all arms inherit the context's worker pool, evaluation cache and
/// observer equally — attach a pool with `EvalContext::with_pool` (or
/// via a request's `threads`) and the comparison stays fair.
pub fn run_method(name: &str, ctx: EvalContext, seed: u64) -> anyhow::Result<Outcome> {
    Ok(match name {
        "sparsemap" => run_sparsemap(ctx, EsConfig::default(), seed),
        "es-pfce" => run_sparsemap(
            ctx,
            EsConfig { variant: EsVariant::Pfce, ..EsConfig::default() },
            seed,
        ),
        "es-direct" => es_direct(ctx, seed),
        "random" => pure_random(ctx, seed),
        "sparseloop" => sparseloop_mapper(ctx, seed),
        "sage-like" => sage_like(ctx, seed),
        "pso" => pso(ctx, seed),
        "mcts" => mcts(ctx, seed),
        "tbpsa" => tbpsa(ctx, seed),
        "ppo" => rl::ppo(ctx, seed),
        "dqn" => rl::dqn(ctx, seed),
        other => anyhow::bail!("unknown method '{other}' (one of {ALL_METHODS:?})"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::search::Backend;
    use crate::workload::Workload;

    #[test]
    fn all_methods_dispatch() {
        for m in ALL_METHODS {
            let w = Workload::spmm("t", 16, 16, 16, 0.5, 0.5);
            let ctx = EvalContext::new(Backend::native(w, Platform::mobile()), 60);
            let o = run_method(m, ctx, 1).unwrap();
            assert!(o.evals <= 60, "{m} overspent");
        }
    }

    #[test]
    fn methods_identical_serial_vs_parallel() {
        // Parallel evaluation must not perturb any arm's trajectory:
        // `pso` exercises `eval_batch`, `es-direct` the foreign-encoding
        // `eval_designs` path.
        for m in ["pso", "es-direct"] {
            let w = Workload::spmm("t", 16, 16, 16, 0.5, 0.5);
            let serial_ctx = EvalContext::new(Backend::native(w.clone(), Platform::mobile()), 200);
            let serial = run_method(m, serial_ctx, 9).unwrap();
            let pool = std::sync::Arc::new(crate::util::threadpool::ThreadPool::new(4));
            let par_ctx = EvalContext::new(Backend::native(w, Platform::mobile()), 200)
                .with_pool(Some(pool));
            let par = run_method(m, par_ctx, 9).unwrap();
            assert_eq!(serial.best_edp, par.best_edp, "{m}");
            assert_eq!(serial.best_genome, par.best_genome, "{m}");
            assert_eq!(serial.curve, par.curve, "{m}");
        }
    }

    #[test]
    fn unknown_method_rejected() {
        let w = Workload::spmm("t", 16, 16, 16, 0.5, 0.5);
        let ctx = EvalContext::new(Backend::native(w, Platform::mobile()), 10);
        assert!(run_method("gradient-descent", ctx, 1).is_err());
    }
}
