//! Cantor (factorial-base) encoding of loop permutations (§IV.C, Eq. 1).
//!
//! Each mapping level orders its D loops by a permutation encoded as a
//! single integer in `[1, D!]`. Cantor encoding's key property (Fig. 10):
//! nearby codes differ mostly in the *inner* loop order, so small gene
//! mutations make small mapping changes — outer-loop order, which
//! dominates accelerator behaviour, maps to the high-order digits.

/// `n!` for small `n`.
pub fn factorial(n: usize) -> u64 {
    (1..=n as u64).product()
}

/// Encode a permutation (a list of distinct dim indices `0..d`) into its
/// 1-based Cantor code: `Σ (a_i - 1)·(d-i)! + 1` where `a_i` is the rank
/// of element i among the not-yet-used values.
pub fn encode(perm: &[usize]) -> u64 {
    let d = perm.len();
    debug_assert!(is_permutation(perm));
    let mut used = vec![false; d];
    let mut code = 0u64;
    for (i, &p) in perm.iter().enumerate() {
        let rank = (0..p).filter(|&j| !used[j]).count() as u64; // 0-based a_i - 1
        code += rank * factorial(d - i - 1);
        used[p] = true;
    }
    code + 1
}

/// Decode a 1-based Cantor code into the permutation of `0..d`.
/// Codes outside `[1, d!]` are wrapped (mod d!) so that any gene value
/// decodes to *some* valid permutation — mutation never produces an
/// undecodable genome.
pub fn decode(code: u64, d: usize) -> Vec<usize> {
    let total = factorial(d);
    let mut c = (code.saturating_sub(1)) % total;
    let mut avail: Vec<usize> = (0..d).collect();
    let mut out = Vec::with_capacity(d);
    for i in 0..d {
        let f = factorial(d - i - 1);
        let idx = (c / f) as usize;
        c %= f;
        out.push(avail.remove(idx));
    }
    out
}

/// Is `xs` a permutation of `0..xs.len()`?
pub fn is_permutation(xs: &[usize]) -> bool {
    let mut seen = vec![false; xs.len()];
    for &x in xs {
        if x >= xs.len() || seen[x] {
            return false;
        }
        seen[x] = true;
    }
    true
}

/// Kendall-tau distance between two permutations (number of discordant
/// pairs) — used by tests to verify the locality property of the encoding.
pub fn kendall_tau(a: &[usize], b: &[usize]) -> usize {
    assert_eq!(a.len(), b.len());
    let d = a.len();
    let pos_b: Vec<usize> = {
        let mut p = vec![0; d];
        for (i, &x) in b.iter().enumerate() {
            p[x] = i;
        }
        p
    };
    let mapped: Vec<usize> = a.iter().map(|&x| pos_b[x]).collect();
    let mut count = 0;
    for i in 0..d {
        for j in (i + 1)..d {
            if mapped[i] > mapped[j] {
                count += 1;
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorials() {
        assert_eq!(factorial(0), 1);
        assert_eq!(factorial(3), 6);
        assert_eq!(factorial(4), 24);
    }

    #[test]
    fn code_1_is_identity() {
        // Paper: code 1 corresponds to permutation MKN (identity order).
        assert_eq!(decode(1, 3), vec![0, 1, 2]);
        assert_eq!(encode(&[0, 1, 2]), 1);
    }

    #[test]
    fn roundtrip_all_d3_d4() {
        for d in [3usize, 4] {
            for code in 1..=factorial(d) {
                let p = decode(code, d);
                assert!(is_permutation(&p));
                assert_eq!(encode(&p), code, "d={d} code={code}");
            }
        }
    }

    #[test]
    fn codes_bijective() {
        let mut seen = std::collections::HashSet::new();
        for code in 1..=6u64 {
            seen.insert(decode(code, 3));
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn out_of_range_wraps() {
        assert_eq!(decode(7, 3), decode(1, 3));
        assert_eq!(decode(0, 3), decode(1, 3)); // 0 saturates to the first code
        assert!(is_permutation(&decode(u64::MAX, 4)));
    }

    #[test]
    fn locality_adjacent_codes_share_outer_loop() {
        // The defining property vs random encoding: adjacent Cantor codes
        // agree on the outermost loop in most cases (they only differ in
        // low-order factorial digits).
        let d = 3;
        let mut share = 0;
        for code in 1..factorial(d) {
            let a = decode(code, d);
            let b = decode(code + 1, d);
            if a[0] == b[0] {
                share += 1;
            }
        }
        // 3 of 5 adjacent pairs share the outer loop for d=3 (code pairs
        // crossing a (d-1)! boundary change it; the rest keep it).
        assert!(share >= 3, "share={share}");
    }

    #[test]
    fn kendall_tau_sanity() {
        assert_eq!(kendall_tau(&[0, 1, 2], &[0, 1, 2]), 0);
        assert_eq!(kendall_tau(&[0, 1, 2], &[2, 1, 0]), 3);
        assert_eq!(kendall_tau(&[0, 1, 2], &[0, 2, 1]), 1);
    }
}
